"""Attention implementations vs the naive oracle (shape/dtype/window sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention_banded, attention_blockwise,
                                    attention_decode, attention_reference)

CASES = [
    # B, S, Hq, KVH, D, window, kv_block
    (2, 64, 4, 4, 16, None, 16),
    (2, 128, 8, 2, 32, None, 32),
    (1, 64, 4, 1, 16, None, 64),       # MQA
    (2, 128, 4, 2, 16, 32, 32),        # SWA via blockwise
]


@pytest.mark.parametrize("B,S,Hq,KVH,D,window,blk", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blockwise_matches_reference(B, S, Hq, KVH, D, window, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, D), dtype)
    out = attention_blockwise(q, k, v, window=window, kv_block=blk)
    ref = attention_reference(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert np.abs(np.asarray(out, np.float32) -
                  np.asarray(ref, np.float32)).max() < tol


@pytest.mark.parametrize("window,qb", [(16, 16), (32, 16), (24, 32)])
def test_banded_matches_reference(window, qb):
    B, S, Hq, KVH, D = 2, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    out = attention_banded(q, k, v, window=window, q_block=qb)
    ref = attention_reference(q, k, v, window=window)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 2e-5


def test_decode_matches_reference_last_row():
    """Decode attention at position t == row t of full attention."""
    B, S, Hq, KVH, D = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    ref = attention_reference(q, k, v)
    t = S - 1
    out = attention_decode(q[:, t:t + 1], k, v,
                           jnp.arange(S), jnp.int32(t))
    assert np.abs(np.asarray(out[:, 0]) - np.asarray(ref[:, t])).max() < 2e-5


def test_decode_ring_buffer_window():
    """Ring cache with window: decode must ignore evicted positions."""
    B, Hq, KVH, D, W = 1, 2, 1, 8, 8
    S = 20
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    ref = attention_reference(q, k, v, window=W)
    # build ring cache of size W holding the last W positions of t
    t = S - 1
    ring_k = jnp.zeros((B, W, KVH, D))
    ring_v = jnp.zeros((B, W, KVH, D))
    for p in range(S):
        ring_k = ring_k.at[:, p % W].set(k[:, p])
        ring_v = ring_v.at[:, p % W].set(v[:, p])
    s = jnp.arange(W)
    cpos = t - jnp.mod(t - s, W)
    out = attention_decode(q[:, t:t + 1], ring_k, ring_v, cpos,
                           jnp.int32(t), window=W)
    assert np.abs(np.asarray(out[:, 0]) - np.asarray(ref[:, t])).max() < 2e-5
