"""Passive bundle registry: signed index + static bundle files any dumb
file/object server can host — publish atomicity (stale-but-consistent
index), advertisement verification at the fetch edge, retention-aware
carry-forward, and the gc-hooked prune sweep."""
import os

import numpy as np
import pytest

from repro.core import (BundleEntry, BundleIndex, DeltaFormatError,
                        Instruction, LayerStore, PassiveRegistry,
                        decode_index, encode_index, import_delta,
                        inject_payload_update, plan_bundle_chain)
from repro.ft import FaultSpec, inject
from repro.ft.faults import CrashInjected

INS = [Instruction("FROM", "arch", "config"),
       Instruction("COPY", "state", "content")]


def tag(s):
    return f"step-{s:08d}"


def build_steps(tmp_path, rng, steps):
    store = LayerStore(str(tmp_path / "src"), chunk_bytes=512)
    state = {"w": rng.standard_normal(2048).astype(np.float32)}
    store.build_image("ckpt", tag(1), INS, {"state": lambda: state})
    for s in range(2, steps + 1):
        state = {"w": state["w"].copy()}
        state["w"][:128] = rng.standard_normal(128)
        inject_payload_update(store, "ckpt", tag(s - 1), tag(s),
                              {"state": state})
    return store


# -------------------------------------------------------------- the index
def test_index_roundtrip_signature_and_tamper():
    index = BundleIndex(image="ckpt", head=tag(3), generation=7, entries=[
        BundleEntry("", tag(3), "bundles/full__x.rdb", 100, "ab" * 32),
        BundleEntry(tag(1), tag(3), "bundles/a__b.rdb", 40, "cd" * 32)])
    data = encode_index(index, key=b"secret")
    back = decode_index(data, key=b"secret")
    assert back == index
    with pytest.raises(DeltaFormatError):
        decode_index(data, key=b"wrong-key")
    with pytest.raises(DeltaFormatError):
        decode_index(data[:-2], key=b"secret")          # truncated
    flipped = bytearray(data)
    flipped[len(data) // 2] ^= 0xFF
    with pytest.raises(DeltaFormatError):
        decode_index(bytes(flipped), key=b"secret")
    with pytest.raises(DeltaFormatError):
        decode_index(b"not json at all")


# ---------------------------------------------------------------- planner
def test_plan_picks_cheapest_by_advertised_bytes():
    def e(f, t, size):
        return BundleEntry(f, t, f"bundles/{f or 'full'}__{t}.rdb",
                           size, "00" * 32)
    index = BundleIndex(image="ckpt", head="c", entries=[
        e("", "c", 500), e("a", "c", 100), e("a", "b", 30), e("b", "c", 30)])
    chain = plan_bundle_chain(index, ["a"])
    assert [(x.from_tag, x.to_tag) for x in chain] == [("a", "b"),
                                                      ("b", "c")]
    # make the direct hop cheaper -> it wins; skip it -> back to the chain
    index.entry("a", "c").size = 50
    assert [(x.from_tag, x.to_tag) for x in plan_bundle_chain(
        index, ["a"])] == [("a", "c")]
    assert [(x.from_tag, x.to_tag) for x in plan_bundle_chain(
        index, ["a"], skip=[("a", "c")])] == [("a", "b"), ("b", "c")]
    # ties break toward fewer hops
    index.entry("a", "c").size = 60
    assert [(x.from_tag, x.to_tag) for x in plan_bundle_chain(
        index, ["a"])] == [("a", "c")]
    # nothing held: only the full bundle reaches the head
    assert [(x.from_tag, x.to_tag) for x in plan_bundle_chain(
        index, [])] == [("", "c")]
    assert plan_bundle_chain(index, ["c"]) == []        # already there
    assert plan_bundle_chain(index, [], skip=[("", "c")],
                             head="b") is None          # unreachable


# ------------------------------------------------------ publish and fetch
def test_publish_image_layout_fetch_and_apply(tmp_path, rng):
    store = build_steps(tmp_path, rng, 3)
    reg = PassiveRegistry(str(tmp_path / "reg"), key=b"k")
    index = reg.publish_image(store, "ckpt", tag(3), from_tags=[tag(1)])
    assert index.head == tag(3) and index.generation == 1
    assert os.path.exists(os.path.join(reg.root, "ckpt", "index.json"))
    assert os.path.exists(os.path.join(
        reg.root, "ckpt", "bundles", f"{tag(1)}__{tag(3)}.rdb"))
    # a fresh reader round-trips the signed index and applies the full
    # bundle into an empty store
    reread = reg.read_index("ckpt")
    assert reread == index
    full = reread.entry("", tag(3))
    assert full is not None and full.size > 0
    fresh = LayerStore(str(tmp_path / "edge"), chunk_bytes=512)
    import_delta(fresh, reg.fetch_bundle("ckpt", full))
    assert fresh.verify_image("ckpt", tag(3), deep=True) == []


def test_fetch_rejects_truncation_and_bitrot(tmp_path, rng):
    store = build_steps(tmp_path, rng, 2)
    reg = PassiveRegistry(str(tmp_path / "reg"))
    index = reg.publish_image(store, "ckpt", tag(2), from_tags=[tag(1)])
    entry = index.entry(tag(1), tag(2))
    path = os.path.join(reg.root, "ckpt", *entry.path.split("/"))
    good = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(good[:-3])                              # truncated
    with pytest.raises(DeltaFormatError):
        reg.fetch_bundle("ckpt", entry)
    rotten = bytearray(good)
    rotten[len(good) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(rotten))                          # at-rest flip
    with pytest.raises(DeltaFormatError):
        reg.fetch_bundle("ckpt", entry)


def test_publish_carries_forward_chain_and_drops_pruned(tmp_path, rng):
    store = build_steps(tmp_path, rng, 4)
    reg = PassiveRegistry(str(tmp_path / "reg"))
    for s in range(2, 5):                               # trainer cadence
        reg.publish_image(store, "ckpt", tag(s), from_tags=[tag(s - 1)])
    index = reg.read_index("ckpt")
    pairs = {(e.from_tag, e.to_tag) for e in index.entries}
    # the whole per-commit chain stays advertised across publishes
    assert {(tag(s - 1), tag(s)) for s in range(2, 5)} <= pairs
    assert ("", tag(4)) in pairs
    # prune step-2 at the source: the NEXT publish drops every entry
    # touching it, keeps the rest
    assert store.remove_image("ckpt", tag(2))
    index = reg.publish_image(store, "ckpt", tag(4), from_tags=[tag(3)])
    pairs = {(e.from_tag, e.to_tag) for e in index.entries}
    assert not any(tag(2) in p for p in pairs)
    assert (tag(3), tag(4)) in pairs and ("", tag(4)) in pairs


def test_prune_runs_as_gc_hook(tmp_path, rng):
    store = build_steps(tmp_path, rng, 3)
    reg = PassiveRegistry(str(tmp_path / "reg"))
    for s in range(2, 4):
        reg.publish_image(store, "ckpt", tag(s), from_tags=[tag(s - 1)])
    reg.attach_gc(store, "ckpt")
    dead = os.path.join(reg.root, "ckpt", "bundles",
                        f"{tag(1)}__{tag(2)}.rdb")
    assert os.path.exists(dead)
    assert store.remove_image("ckpt", tag(1))
    stats = store.gc()
    assert stats["bundles_pruned"] >= 1
    assert not os.path.exists(dead)                     # file swept too
    pairs = {(e.from_tag, e.to_tag) for e in reg.read_index("ckpt").entries}
    assert not any(tag(1) in p for p in pairs)


def test_crashed_index_write_leaves_stale_consistent_index(tmp_path, rng):
    """Death between the bundle writes and the index rename: readers keep
    the old advertisement (every entry still fetchable) and the restarted
    publisher advances it."""
    store = build_steps(tmp_path, rng, 3)
    reg = PassiveRegistry(str(tmp_path / "reg"))
    old = reg.publish_image(store, "ckpt", tag(2), from_tags=[tag(1)])
    with inject(0, FaultSpec(point="bundle.publish", mode="crash",
                             match=":ckpt:index")):
        with pytest.raises(CrashInjected):
            reg.publish_image(store, "ckpt", tag(3), from_tags=[tag(2)])
    stale = reg.read_index("ckpt")
    assert stale == old                                 # old or new, never torn
    for entry in stale.entries:
        reg.fetch_bundle("ckpt", entry)                 # all still valid
    fresh = reg.publish_image(store, "ckpt", tag(3), from_tags=[tag(2)])
    assert reg.read_index("ckpt") == fresh
    assert fresh.head == tag(3)


def test_dropped_bundle_write_keeps_index_honest(tmp_path, rng):
    """A bundle file that fails to publish is simply NOT advertised — the
    index written afterwards only ever names bundles that landed."""
    store = build_steps(tmp_path, rng, 2)
    reg = PassiveRegistry(str(tmp_path / "reg"))
    with inject(0, FaultSpec(point="bundle.publish", mode="drop",
                             match=f"{tag(1)}->{tag(2)}")):
        index = reg.publish_image(store, "ckpt", tag(2),
                                  from_tags=[tag(1)])
    assert index.entry(tag(1), tag(2)) is None
    full = index.entry("", tag(2))
    assert full is not None
    reg.fetch_bundle("ckpt", full)                      # advertised => real
