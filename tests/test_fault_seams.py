"""Every protocol fault point, exercised by name: the literal seam table
below is pinned (by equality) to ``ft.chaos.SEAMS``, and each seam plus
the scenario-specific points (``relay.fan``, ``store.commit``,
``bundle.fetch``, the source-side ``store.read_blob``) is driven to
convergence here — so the analyzer's R1 coverage contract (every
``fault_point`` in src appears in the chaos matrix AND in a test) is
backed by real, converging injections rather than string-dropping."""
import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, RelayNode,
                        inject_payload_update, push_delta,
                        replicate_fanout)
from repro.ft import CrashInjected, FaultSpec, RetryPolicy, inject
from repro.ft.chaos import SEAMS

INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "src", "content"),
    Instruction("RUN", "deps", "content"),
    Instruction("CMD", "run", "config"),
]

#: literal duplicate of ``ft.chaos.SEAMS`` — kept as literals on purpose:
#: R1 requires each point name to occur in the tests verbatim, and
#: ``test_seam_table_matches_chaos`` fails the build if this copy drifts
SEAM_CASES = [
    ("wire.negotiate", "dst"),
    ("wire.probe_blobs", "dst"),
    ("wire.receive_layer", "dst"),
    ("wire.receive_blob", "dst"),
    ("wire.commit", "dst"),
    ("store.read_blob", "src"),
    ("store.commit", "dst"),
]

FAST = dict(max_attempts=4, base_delay_s=0.001, max_delay_s=0.01)


def mk(tmp_path, name):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


def make_payloads(rng):
    return {
        "src": {"a": rng.standard_normal(1000).astype(np.float32),
                "b": rng.standard_normal(500).astype(np.float32)},
        "deps": {"lib": rng.standard_normal(4000).astype(np.float32)},
    }


def build_v1(store, payloads):
    store.build_image("app", "v1", INS,
                      {k: (lambda v=v: v) for k, v in payloads.items()})


def inject_v2(store, payloads):
    src2 = {k: v.copy() for k, v in payloads["src"].items()}
    src2["b"][3] = 42.0
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"deps": lambda: payloads["deps"]})


def converged(src, dst):
    assert dst.verify_image("app", "v2", deep=True) == []
    m_src, _ = src.read_image("app", "v2")
    m_dst, _ = dst.read_image("app", "v2")
    assert m_src.layer_ids == m_dst.layer_ids


def test_seam_table_matches_chaos():
    """The literal seam list above IS the chaos rotation table — a seam
    added to one without the other fails here before R1 ever runs."""
    assert tuple(SEAM_CASES) == SEAMS


@pytest.mark.parametrize("point,side", SEAM_CASES,
                         ids=[p for p, _ in SEAM_CASES])
def test_drop_at_each_seam_converges(tmp_path, rng, point, side):
    """One dropped hit at every protocol seam — negotiate, probe, layer
    and blob receive, remote commit, the source's own disk read, the
    store commit point — must be converged by the in-run retry."""
    src, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(src, payloads)
    push_delta(src, dst, "app", "v1")
    inject_v2(src, payloads)
    match = src.root if side == "src" else dst.root
    policy = RetryPolicy(seed=0, **FAST)
    with inject(0, FaultSpec(point=point, mode="drop", match=match,
                             times=1)) as inj:
        push_delta(src, dst, "app", "v2", retry=policy)
    assert inj.fired() >= 1, f"{point} never fired — seam wiring broken?"
    converged(src, dst)


def test_source_read_failure_fails_takers_not_fan(tmp_path, rng):
    """The ship() isolation contract: a source-side store.read_blob drop
    fails only that blob's takers — the healthy replicas commit on the
    first pass and the retry converges the rest. Before this seam was
    guarded, one bad source read crashed the whole fan un-retried."""
    src, r0, r1, r2 = (mk(tmp_path, n) for n in ("src", "r0", "r1", "r2"))
    payloads = make_payloads(rng)
    build_v1(src, payloads)
    replicate_fanout(src, [r0, r1, r2], "app", "v1")
    inject_v2(src, payloads)
    policy = RetryPolicy(seed=1, **FAST)
    with inject(1, FaultSpec(point="store.read_blob", mode="drop",
                             match=src.root, times=1)) as inj:
        fan = replicate_fanout(src, [r0, r1, r2], "app", "v2",
                               retry=policy)
    assert inj.fired() >= 1
    assert fan.n_ok == 3, "retry did not converge the failed takers"
    for d in (r0, r1, r2):
        converged(src, d)


def test_source_crash_propagates_and_restart_converges(tmp_path, rng):
    """CrashInjected at the source read is the PUSHER dying — it must
    escape (never be folded into per-replica isolation) and the
    restarted pusher must converge."""
    src, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(src, payloads)
    push_delta(src, dst, "app", "v1")
    inject_v2(src, payloads)
    with inject(2, FaultSpec(point="store.read_blob", mode="crash",
                             match=src.root, times=1)):
        with pytest.raises(CrashInjected):
            push_delta(src, dst, "app", "v2",
                       retry=RetryPolicy(seed=2, **FAST))
        push_delta(src, dst, "app", "v2")    # the restarted pusher
    converged(src, dst)


def test_bundle_fetch_drop_falls_back_to_remote(tmp_path, rng):
    """bundle.fetch dropped for every passive file: the follower must
    detect the unreachable registry and fall back to the smart remote
    pull, converging in the same poll."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.core import PassiveRegistry
    from repro.serve import CheckpointFollower
    reg = PassiveRegistry(str(tmp_path / "registry"))
    mgr = CheckpointManager(
        str(tmp_path / "train"), "t",
        CheckpointPolicy(async_write=False, chunk_bytes=512, keep=0),
        registry=reg)
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    mgr.save(0, params, {"m": np.zeros(8, np.float32)})
    local = mk(tmp_path, "local")
    follower = CheckpointFollower(mgr.store, local, keep=3,
                                  retry=RetryPolicy(seed=4, **FAST),
                                  registry=reg)
    with inject(4, FaultSpec(point="bundle.fetch", mode="drop",
                             match=reg.root, times=None)) as inj:
        upd = follower.poll()
    assert inj.fired("bundle.fetch") >= 1
    assert upd is not None and upd.step == 0
    assert local.verify_image(mgr.image, "step-00000000", deep=True) == []


@pytest.mark.parametrize("mode", ["drop", "crash"])
def test_relay_fan_fault_converges_via_retry(tmp_path, rng, mode):
    """relay.fan struck at the mid tier: the fan attempt dies, the
    outer retry pass re-fans, and both edge children still converge
    bit-identically."""
    src, mid, e0, e1 = (mk(tmp_path, n) for n in ("src", "mid", "e0",
                                                  "e1"))
    payloads = make_payloads(rng)
    build_v1(src, payloads)
    policy = RetryPolicy(seed=3, **FAST)
    relay = RelayNode(mid, children=[e0, e1], retry=policy)
    replicate_fanout(src, [relay], "app", "v1")
    inject_v2(src, payloads)
    with inject(3, FaultSpec(point="relay.fan", mode=mode,
                             match=mid.root, times=1)) as inj:
        fan = replicate_fanout(src, [relay], "app", "v2", retry=policy)
    assert inj.fired("relay.fan") == 1
    rep = fan.replicas[0]
    assert rep.ok, f"relay tier failed: {rep.error}"
    assert rep.children is not None and rep.children.n_ok == 2
    for d in (mid, e0, e1):
        converged(src, d)

def test_follower_pull_key_names_the_image(tmp_path, rng):
    """The follower.pull key is <local.root>:<image>:<tag> — a spec
    matching ':alpha:' must strike ONLY the alpha follower. Before the
    image joined the key, two tenants sharing a host were
    indistinguishable to the injector and this match never fired."""
    from repro.serve import CheckpointFollower
    remote = mk(tmp_path, "remote")
    state = {"w": rng.standard_normal(600).astype(np.float32)}
    ins = [Instruction("FROM", "arch", "config"),
           Instruction("COPY", "state", "content")]
    for image in ("alpha", "beta"):
        remote.build_image(image, "step-00000001", ins,
                           {"state": lambda: state})
    host = mk(tmp_path, "host")          # one shared serving store
    fol_a = CheckpointFollower(remote, host, image="alpha", keep=3)
    fol_b = CheckpointFollower(remote, host, image="beta", keep=3)
    with inject(0, FaultSpec(point="follower.pull", mode="drop",
                             match=":alpha:", times=None)) as inj:
        upd = fol_b.poll()               # beta is untouched by the spec
        assert upd is not None and upd.step == 1
        with pytest.raises(ConnectionError):
            fol_a.poll()
    assert inj.fired("follower.pull") == 1
    assert fol_a.poll().step == 1        # next tick converges alpha
    assert host.verify_image("alpha", "step-00000001", deep=True) == []


def test_crash_during_incremental_save_surfaces(tmp_path, rng):
    """CrashInjected inside the batched incremental transaction is the
    SAVER dying — it must escape save(), never be misread as 'structure
    changed' and silently re-run as a full rebuild (which would mark the
    kill-matrix cell green without any process death)."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    mgr = CheckpointManager(
        str(tmp_path / "train"), "t",
        CheckpointPolicy(async_write=False, chunk_bytes=512))
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr.save(0, params, opt)
    params2 = dict(params, w=params["w"] + 1.0)
    with inject(5, FaultSpec(point="store.commit", mode="crash",
                             match=mgr.store.root, times=1)) as inj:
        with pytest.raises(CrashInjected):
            mgr.save(1, params2, opt)
        assert mgr.latest_step() == 0    # the batch never committed
        mgr.save(1, params2, opt)        # the restarted saver
    assert inj.fired("store.commit") == 1
    assert mgr.latest_step() == 1
    assert mgr.store.verify_image(mgr.image, "step-00000001",
                                  deep=True) == []


def test_crash_during_inline_repair_surfaces_from_poll(tmp_path, rng):
    """CrashInjected while the verify gate heals a rotted revision is the
    FOLLOWER dying mid-repair — poll() must raise it (a supervisor
    restarts the replica), not log 'repair failed' and keep serving; the
    restarted follower's next poll re-repairs and converges."""
    from repro.serve import CheckpointFollower
    remote, local = mk(tmp_path, "remote"), mk(tmp_path, "local")
    state = {"params/w": rng.standard_normal(1000).astype(np.float32),
             "opt/__step__": np.asarray([1], np.int32)}
    ins = [Instruction("FROM", "arch", "config"),
           Instruction("COPY", "state", "content")]
    remote.build_image("ckpt", "step-00000001", ins,
                       {"state": lambda: state})
    follower = CheckpointFollower(remote, local, keep=3)
    assert follower.poll().step == 1     # warm base, no faults
    state2 = {k: v.copy() for k, v in state.items()}
    state2["params/w"][7] = 42.0
    state2["opt/__step__"][0] = 2
    inject_payload_update(remote, "ckpt", "step-00000001",
                          "step-00000002", {"state": state2})
    specs = [FaultSpec(point="store.write_blob", mode="bitrot",
                       match=local.root, times=1),
             FaultSpec(point="repair.pull", mode="crash",
                       match=local.root, times=1)]
    with inject(6, *specs) as inj:
        with pytest.raises(CrashInjected):
            follower.poll()              # rot detected, repair crashes
        # times=1 is per (point, key): every damaged blob's first repair
        # pull dies once, so keep restarting the follower (supervisor
        # semantics) until one whole poll survives
        upd = None
        for _ in range(8):
            try:
                upd = follower.poll()
            except CrashInjected:
                continue
            break
    assert inj.fired("store.write_blob") >= 1
    assert inj.fired("repair.pull") >= 1
    assert upd is not None and upd.step == 2
    assert local.verify_image("ckpt", "step-00000002", deep=True) == []
