"""Distribution tests on 8 forced host devices (subprocess: the main test
process must keep 1 device for everything else)."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.models import init_params, loss_fn
        from repro.optim import init_opt_state
        from repro.train import TrainConfig, make_train_step

        cfg = get_smoke_config("yi-6b")
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S = 8, 32
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        opt = init_opt_state(params)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
                 "mask": jnp.ones((B, S), jnp.float32)}
        # unsharded reference loss
        ref_loss = float(loss_fn(cfg, params, batch)[0])
        with mesh_context(mesh):
            bundle = make_train_step(cfg, TrainConfig(microbatches=1),
                                     mesh, B, S)
            p2, o2, metrics = bundle.fn(params, opt, batch)
        got = float(metrics["loss"])
        assert abs(got - ref_loss) < 5e-2, (got, ref_loss)
        assert np.isfinite(float(metrics["grad_norm"]))
        print("OK", got, ref_loss)
    """)
    assert "OK" in out


def test_microbatched_equals_full_batch_grads():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.models import init_params
        from repro.optim import init_opt_state
        from repro.train import TrainConfig, make_train_step

        cfg = get_smoke_config("musicgen-medium")
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S = 8, 16
        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        outs = []
        for nm in (1, 4):
            # fresh state per run (bundle.fn donates its inputs), created
            # OUTSIDE the mesh context so jit reshards uncommitted arrays
            params = init_params(cfg, key)
            opt = init_opt_state(params)
            batch = {"tokens": tokens,
                     "labels": jnp.roll(tokens, -1, 1),
                     "mask": jnp.ones((B, S), jnp.float32)}
            with mesh_context(mesh):
                bundle = make_train_step(cfg, TrainConfig(microbatches=nm),
                                         mesh, B, S)
                p2, _, m = bundle.fn(params, opt, batch)
            outs.append(p2)
        d = max(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(outs[0]),
                                jax.tree.leaves(outs[1])))
        assert d < 3e-2, d    # bf16 params; microbatch loss-mean != exact
        print("OK", d)
    """)
    assert "OK" in out


def test_compressed_psum_matches_mean():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim import compressed_psum
        from repro.sharding.ctx import shard_map_fn
        shard_map = shard_map_fn()

        mesh = make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))
        err = jnp.zeros((8, 4096))

        def f(g, e):
            mean, new_e = compressed_psum(g[0], e[0], ("data",))
            return mean[None], new_e[None]

        fm = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")))
        mean, new_err = fm(g, err)
        ref = jnp.mean(g, axis=0)
        got = np.asarray(mean[0])
        scale = float(jnp.abs(g).max()) / 127.0
        assert np.abs(got - np.asarray(ref)).max() < 2 * scale
        # error feedback: err ~= what quantization lost
        assert np.isfinite(np.asarray(new_err)).all()
        print("OK")
    """)
    assert "OK" in out


def test_multipod_mesh_and_decode_cell():
    """End-to-end mini dry-run inside the test suite (64 fake devices)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.models import init_cache, init_params
        from repro.train import make_decode_step

        cfg = get_smoke_config("mixtral-8x7b")
        mesh = make_mesh((2, 4, 8), ("pod", "data", "model"))
        B, C = 8, 64
        with mesh_context(mesh):
            bundle = make_decode_step(cfg, mesh, B, C)
            pshape = bundle.abstract_inputs[0]
            cshape = bundle.abstract_inputs[1]
            toks = jax.ShapeDtypeStruct((B,), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            compiled = bundle.fn.lower(pshape, cshape, toks, pos).compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, list):   # older jax: one dict per computation
                ca = ca[0]
            print("OK", ca.get("flops", 0) > 0)
    """, n=64)
    assert "OK True" in out


def test_moe_local_shard_map_matches_unsharded():
    """granite-style fully-local MoE (shard_map + replicated experts) must
    compute the same loss as the unsharded model (capacity effects differ
    only when shards drop different tokens — use ample capacity)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.models import init_params, loss_fn
        from repro.sharding.ctx import activation_ctx
        from repro.sharding.rules import (Recipe, activation_rules,
                                          batch_specs, param_specs_tree)
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_smoke_config("granite-moe-3b-a800m").replace(
            capacity_factor=8.0)
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S = 8, 32
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
                 "mask": jnp.ones((B, S), jnp.float32)}
        ref = float(loss_fn(cfg, params, batch)[0])

        recipe = Recipe("sp", "train")   # the granite full-config recipe
        arules = activation_rules(cfg, recipe, mesh, B)
        assert arules.get("moe_local") is not None, "moe_local rule missing"
        pspec = param_specs_tree(cfg, recipe, mesh,
                                 jax.eval_shape(lambda: params))
        named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                             is_leaf=lambda x: isinstance(x, P))

        def f(p, b):
            with activation_ctx(arules):
                return loss_fn(cfg, p, b)[0]

        with mesh_context(mesh):
            got = float(jax.jit(f, in_shardings=(named, {
                k: NamedSharding(mesh, s) for k, s in
                batch_specs(cfg, recipe, mesh, B).items()}))(params, batch))
        assert abs(got - ref) < 5e-2, (got, ref)
        print("OK", got, ref)
    """)
    assert "OK" in out
