"""Squashed static delta chains: the per-commit delta records the
injection path already writes into the config history, composed into ONE
bundle that replays bit-identically — repeated overwrites of the same
chunk collapse to the final bytes, re-key-only spans ship no payload."""
import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, compose_delta_records,
                        encode_delta, history_delta_chain, import_delta,
                        inject_payload_update, push, squash_deltas,
                        verify_squashed_bundle)
from repro.core import registry as registry_mod

INS = [
    Instruction("FROM", "arch", "config"),
    Instruction("COPY", "state", "content"),
    Instruction("COPY", "extra", "content"),
    Instruction("CMD", "serve", "config"),
]


def tag(s):
    return f"step-{s:08d}"


def mk(tmp_path, name):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


def build_chain(store, rng, steps, touch_extra=()):
    """step-1 .. step-<steps>; every hop rewrites the SAME head chunk of
    'state' (the bytes a squash must collapse); hops in ``touch_extra``
    also rewrite 'extra' (the bytes it must keep)."""
    state = {"w": rng.standard_normal(2048).astype(np.float32)}
    extra = {"e": rng.standard_normal(512).astype(np.float32)}
    store.build_image("ckpt", tag(1), INS,
                      {"state": lambda: state, "extra": lambda: extra})
    for s in range(2, steps + 1):
        state = {"w": state["w"].copy()}
        state["w"][:128] = rng.standard_normal(128)     # same 512 B chunk
        payload = {"state": state}
        if s in touch_extra:
            extra = {"e": extra["e"].copy()}
            extra["e"][0] = float(s)
            payload["extra"] = extra
        inject_payload_update(store, "ckpt", tag(s - 1), tag(s), payload)
    return state, extra


# ------------------------------------------------------------ composition
def test_compose_single_record_kinds():
    rec = {"injected": {"b2": "b1"}, "rekeyed": {"c2": "c1"},
           "rederived": {"d2": "d1"}}
    origin = compose_delta_records([rec])
    assert origin == {"b2": ("b1", True), "c2": ("c1", False),
                      "d2": ("d1", True)}


def test_compose_chains_identity_and_changed_flag():
    # injected once then re-keyed twice: ONE content change vs the base;
    # a layer only ever re-keyed composes to unchanged
    records = [{"injected": {"b2": "b1"}, "rekeyed": {"c2": "c1"}},
               {"rekeyed": {"b3": "b2", "c3": "c2"}},
               {"rekeyed": {"b4": "b3"}, "rederived": {"c4": "c3"}}]
    origin = compose_delta_records(records)
    assert origin["b4"] == ("b1", True)
    assert origin["c4"] == ("c1", True)      # rederived at the last hop
    assert "b2" not in origin and "c2" not in origin   # intermediate ids


def test_history_delta_chain_suffix_per_base(tmp_path, rng):
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=4)
    _, config = store.read_image("ckpt", tag(4))
    chain = history_delta_chain(config, "ckpt", tag(1))
    assert chain is not None and len(chain) == 3
    assert [c["base"][1] for c in chain] == [tag(1), tag(2), tag(3)]
    assert len(history_delta_chain(config, "ckpt", tag(3))) == 1
    assert history_delta_chain(config, "ckpt", "step-99999999") is None
    assert history_delta_chain(config, "other-image", tag(1)) is None


# ------------------------------------------------------------- squashing
def test_squash_replays_bit_identically(tmp_path, rng):
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=5, touch_extra=(3,))
    bundle = squash_deltas(store, "ckpt", tag(1), tag(5))
    assert bundle.base_tag == tag(1) and bundle.tag == tag(5)
    assert verify_squashed_bundle(store, bundle) == []


def test_squash_collapses_same_chunk_overwrites(tmp_path, rng):
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=6)
    per_hop_blobs = sum(
        len(squash_deltas(store, "ckpt", tag(s - 1), tag(s)).blobs)
        for s in range(2, 7))
    squashed = squash_deltas(store, "ckpt", tag(1), tag(6))
    # 5 hops each rewrote the same chunk: the squash ships it ONCE, with
    # the final bytes — not once per hop
    assert per_hop_blobs >= 5
    assert len(squashed.blobs) < per_hop_blobs
    assert verify_squashed_bundle(store, squashed) == []


def test_squash_rekey_only_layers_ship_no_payload(tmp_path, rng):
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=4)         # 'extra' never touched
    bundle = squash_deltas(store, "ckpt", tag(1), tag(4))
    assert bundle.rekey                      # downstream layers re-keyed
    # no blob in the bundle belongs to the untouched 'extra' layer
    from_manifest, _ = store.read_image("ckpt", tag(1))
    extra_chunks = {h for lid in from_manifest.layer_ids
                    for rec in store.read_layer(lid).records
                    for h in rec.chunks
                    if store.read_layer(lid).instruction.arg == "extra"}
    assert extra_chunks.isdisjoint(bundle.blobs)


def test_squash_forced_fallback_matches_history_path(tmp_path, rng,
                                                     monkeypatch):
    """The diff_manifests fallback (history unrecoverable) must derive
    the SAME bundle the composed-history path does."""
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=5, touch_extra=(2, 4))
    fast = squash_deltas(store, "ckpt", tag(1), tag(5))
    monkeypatch.setattr(registry_mod, "history_delta_chain",
                        lambda *a, **k: None)
    slow = squash_deltas(store, "ckpt", tag(1), tag(5))
    assert fast.rekey == slow.rekey
    assert fast.blobs == slow.blobs
    assert [ly.layer_id for ly in fast.layers] == \
        [ly.layer_id for ly in slow.layers]
    assert verify_squashed_bundle(store, slow) == []


def test_squash_applies_through_import_delta(tmp_path, rng):
    store, follower = mk(tmp_path, "src"), mk(tmp_path, "dst")
    build_chain(store, rng, steps=4)
    push(store, follower, "ckpt", tag(1))
    data = encode_delta(squash_deltas(store, "ckpt", tag(1), tag(4)))
    import_delta(follower, data)
    assert follower.verify_image("ckpt", tag(4), deep=True) == []
    m_src, _ = store.read_image("ckpt", tag(4))
    m_dst, _ = follower.read_image("ckpt", tag(4))
    assert m_src.layer_ids == m_dst.layer_ids
    for lid in m_src.layer_ids:
        for rec in store.read_layer(lid).records:
            for h in rec.chunks:
                assert follower.read_blob(h) == store.read_blob(h)


def test_squash_releases_endpoint_leases(tmp_path, rng):
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=3)
    squash_deltas(store, "ckpt", tag(1), tag(3))
    assert not store.leased("ckpt", tag(1))
    assert not store.leased("ckpt", tag(3))


def test_squash_endpoints_survive_concurrent_prune(tmp_path, rng):
    """The leases are load-bearing: mid-squash, a retention sweep must
    refuse to collect either endpoint tag."""
    from repro.ckpt.manager import prune_steps
    store = mk(tmp_path, "src")
    build_chain(store, rng, steps=4)

    pruned_during = {}
    orig = registry_mod.history_delta_chain

    def raced(*a, **k):
        # runs inside squash_deltas, after both leases are held
        prune_steps(store, "ckpt", keep=1)
        pruned_during["tags"] = set(store.list_tags("ckpt"))
        return orig(*a, **k)

    registry_mod.history_delta_chain = raced
    try:
        bundle = squash_deltas(store, "ckpt", tag(1), tag(4))
    finally:
        registry_mod.history_delta_chain = orig
    assert {tag(1), tag(4)} <= pruned_during["tags"]
    assert verify_squashed_bundle(store, bundle) == []
