"""Optimizer: AdamW reference math, schedule, gradient compression."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, apply_update, init_opt_state, lr_at,
                         quantize_int8, dequantize_int8)


def test_adamw_matches_manual():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10**9,
                      min_lr_ratio=1.0, weight_decay=0.1, grad_clip=1e9)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = init_opt_state(params)
    g = {"w": jnp.full((4,), 0.5, jnp.bfloat16)}
    new_p, new_o, stats = apply_update(cfg, params, opt, g)
    # manual step 1
    m = 0.1 * 0.5
    v = 0.05 * 0.25
    mh, vh = m / 0.1, v / 0.05
    expect = 1.0 - 1e-2 * (mh / (np.sqrt(vh) + cfg.eps) + 0.1 * 1.0)
    assert np.allclose(np.asarray(new_o["master"]["w"]), expect, atol=1e-6)
    assert new_o["step"] == 1


def test_lr_schedule():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0, warmup_steps=0,
                      decay_steps=10**9, min_lr_ratio=1.0, peak_lr=1.0)
    params = {"w": jnp.zeros((100,), jnp.float32)}
    opt = init_opt_state(params)
    g = {"w": jnp.full((100,), 10.0)}
    _, new_o, stats = apply_update(cfg, params, opt, g)
    assert float(stats["grad_norm"]) == pytest.approx(100.0)
    # clipped g = 10/100.0... scale=1/100 -> g=0.1 -> m = 0.01
    assert np.allclose(np.asarray(new_o["m"]["w"]), 0.01, atol=1e-6)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(5000).astype(np.float32))
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, g.shape, jnp.float32)
    err = np.abs(np.asarray(back - g))
    assert err.max() <= float(np.abs(np.asarray(g)).max()) / 127.0 + 1e-6
