"""Passive pulls end-to-end: a CheckpointManager that publishes static
bundles after every save, and a CheckpointFollower that converges from
those plain files alone — zero negotiation round-trips, cheapest
advertised chain, and never a raised poll when the index goes stale,
a bundle rots, or a referenced tag was pruned."""
import os

import numpy as np
import pytest

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.core import PassiveRegistry, plan_bundle_chain
from repro.core.registry import DeltaReceiver
from repro.serve import CheckpointFollower


def tag(s):
    return f"step-{s:08d}"


def make_publisher(tmp_path, rng, steps, **policy_kw):
    """A trainer that saves ``steps`` checkpoints, publishing into a
    passive registry after every save (spans 1/4/8 back)."""
    reg = PassiveRegistry(str(tmp_path / "registry"))
    mgr = CheckpointManager(
        str(tmp_path / "train"), "t",
        CheckpointPolicy(async_write=False, chunk_bytes=512, keep=0,
                         **policy_kw),
        registry=reg)
    params = {"w": rng.standard_normal(600).astype(np.float32),
              "b": rng.standard_normal(64).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    for step in range(steps):
        if step:
            params = dict(params, w=params["w"].copy())
            params["w"][:64] = rng.standard_normal(64)  # same hot chunk
        mgr.save(step, params, opt)
        assert mgr.last_publish_error is None
    return mgr, reg, params


def no_negotiate(monkeypatch):
    """Counter-proof: the passive path must never open a negotiation —
    make any attempt a hard failure."""
    monkeypatch.setattr(
        DeltaReceiver, "negotiate",
        lambda self, *a, **k: (_ for _ in ()).throw(
            AssertionError("negotiate() called on the passive path")))


def test_manager_publishes_spans_after_save(tmp_path, rng):
    mgr, reg, _ = make_publisher(tmp_path, rng, steps=9)
    index = reg.read_index(mgr.image)
    assert index.head == tag(8)
    assert mgr.last_publish is not None
    pairs = {(e.from_tag, e.to_tag) for e in index.entries}
    # spans 1, 4, 8 back from the head, plus the full bundle
    assert {(tag(7), tag(8)), (tag(4), tag(8)), (tag(0), tag(8)),
            ("", tag(8))} <= pairs


def test_publish_failure_never_fails_the_save(tmp_path, rng,
                                              monkeypatch):
    mgr, reg, params = make_publisher(tmp_path, rng, steps=2)
    monkeypatch.setattr(PassiveRegistry, "publish_image",
                        lambda *a, **k: (_ for _ in ()).throw(
                            OSError("registry volume offline")))
    params = dict(params, w=params["w"] + 1.0)
    mgr.save(2, params, {"m": np.zeros(8, np.float32)})    # must not raise
    assert mgr.last_publish_error is not None
    assert "offline" in mgr.last_publish_error
    assert mgr.store.has_image(mgr.image, tag(2))          # save landed


def test_passive_only_follower_zero_negotiation(tmp_path, rng,
                                                monkeypatch):
    """No smart remote AT ALL (remote=None): the follower bootstraps from
    the full bundle and then rides squashed bundles — plain file reads,
    counter-proved zero negotiation rounds."""
    no_negotiate(monkeypatch)
    mgr, reg, params = make_publisher(tmp_path, rng, steps=9)
    fol = CheckpointFollower(None, str(tmp_path / "serve"),
                             image=mgr.image, keep=12, registry=reg)
    upd = fol.poll()
    assert upd is not None and upd.step == 8 and upd.full
    assert np.array_equal(np.asarray(upd.params["w"]), params["w"])
    plan = fol.last_plan
    assert plan.negotiations == 0 and plan.fallback == ""
    assert plan.hops == 1                    # the full bundle, one edge
    assert fol.local.verify_image(mgr.image, tag(8), deep=True) == []
    assert fol.poll() is None                # up to date, still no raise


def test_lagging_follower_takes_one_squashed_hop(tmp_path, rng,
                                                 monkeypatch):
    """8 commits behind: the planner picks the single squashed bundle
    over the per-commit chain and the full pull, and the pull costs
    exactly the advertised bytes."""
    no_negotiate(monkeypatch)
    reg = PassiveRegistry(str(tmp_path / "registry"))
    mgr = CheckpointManager(
        str(tmp_path / "train"), "t",
        CheckpointPolicy(async_write=False, chunk_bytes=512, keep=0),
        registry=reg)
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr.save(0, params, opt)
    fol = CheckpointFollower(None, str(tmp_path / "serve"),
                             image=mgr.image, keep=12, registry=reg)
    assert fol.poll().step == 0              # warm at the old head
    for step in range(1, 9):
        params = dict(params, w=params["w"].copy())
        params["w"][:64] = rng.standard_normal(64)
        mgr.save(step, params, opt)
    index = reg.read_index(mgr.image)
    cheapest = sum(e.size for e in plan_bundle_chain(index, [tag(0)]))
    upd = fol.poll()
    assert upd is not None and upd.step == 8
    plan = fol.last_plan
    assert plan.hops == 1 and plan.negotiations == 0
    assert plan.bytes_pulled == plan.planned_bytes == cheapest
    full = index.entry("", tag(8))
    assert plan.bytes_pulled < full.size     # beat the full pull
    assert np.array_equal(np.asarray(upd.params["w"]), params["w"])
    assert fol.local.verify_image(mgr.image, tag(8), deep=True) == []


def test_poll_survives_index_referencing_pruned_bundle(tmp_path, rng,
                                                       monkeypatch):
    """The regression this PR fixes: a stale index may advertise a chain
    whose bundle the publisher's retention already swept. The planner
    must skip the dead edge and replan mid-poll — never raise."""
    no_negotiate(monkeypatch)
    mgr, reg, params = make_publisher(tmp_path, rng, steps=1)
    fol = CheckpointFollower(None, str(tmp_path / "serve"),
                             image=mgr.image, keep=12, registry=reg)
    assert fol.poll().step == 0
    opt = {"m": np.zeros(8, np.float32)}
    for step in range(1, 9):
        params = dict(params, w=params["w"].copy())
        params["w"][:64] = rng.standard_normal(64)
        mgr.save(step, params, opt)
    # sweep the exact bundle the plan would take, WITHOUT republishing
    index = reg.read_index(mgr.image)
    doomed = plan_bundle_chain(index, [tag(0)])[0]
    os.remove(os.path.join(reg.root, mgr.image, *doomed.path.split("/")))
    upd = fol.poll()                         # must not raise
    assert upd is not None and upd.step == 8
    assert fol.last_plan.edges_skipped >= 1
    assert fol.local.verify_image(mgr.image, tag(8), deep=True) == []


def test_rotten_bundle_skipped_and_replanned(tmp_path, rng, monkeypatch):
    no_negotiate(monkeypatch)
    mgr, reg, params = make_publisher(tmp_path, rng, steps=1)
    fol = CheckpointFollower(None, str(tmp_path / "serve"),
                             image=mgr.image, keep=12, registry=reg)
    assert fol.poll().step == 0
    opt = {"m": np.zeros(8, np.float32)}
    for step in range(1, 5):
        params = dict(params, w=params["w"].copy())
        params["w"][:64] = rng.standard_normal(64)
        mgr.save(step, params, opt)
    index = reg.read_index(mgr.image)
    victim = plan_bundle_chain(index, [tag(0)])[0]
    path = os.path.join(reg.root, mgr.image, *victim.path.split("/"))
    rotten = bytearray(open(path, "rb").read())
    rotten[len(rotten) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(rotten))
    upd = fol.poll()                         # hash mismatch -> replan
    assert upd is not None and upd.step == 4
    assert fol.last_plan.edges_skipped >= 1
    assert np.array_equal(np.asarray(upd.params["w"]), params["w"])


def test_no_usable_chain_falls_back_to_smart_remote(tmp_path, rng):
    mgr, reg, params = make_publisher(tmp_path, rng, steps=3)
    # every advertised bundle vanishes; the index itself stays up
    bundles = os.path.join(reg.root, mgr.image, "bundles")
    for f in os.listdir(bundles):
        os.remove(os.path.join(bundles, f))
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"),
                             image=mgr.image, keep=12, registry=reg)
    upd = fol.poll()
    assert upd is not None and upd.step == 2
    assert fol.last_plan is not None
    assert fol.last_plan.fallback == "remote"
    assert np.array_equal(np.asarray(upd.params["w"]), params["w"])


def test_passive_only_no_chain_returns_none(tmp_path, rng):
    """Passive-only follower with nothing fetchable: poll reports
    "nothing applied" (None) — a quiet retry-next-poll, not a failure."""
    mgr, reg, _ = make_publisher(tmp_path, rng, steps=2)
    bundles = os.path.join(reg.root, mgr.image, "bundles")
    for f in os.listdir(bundles):
        os.remove(os.path.join(bundles, f))
    fol = CheckpointFollower(None, str(tmp_path / "serve"),
                             image=mgr.image, registry=reg)
    assert fol.poll() is None
    assert fol.health().consecutive_failures == 0
    assert fol.last_step is None


def test_stale_index_newer_remote_head_wins(tmp_path, rng):
    """The index trails the trainer (publish crashed, volume lagged): a
    follower with BOTH channels must chase the remote's newer head, not
    the stale advertisement."""
    mgr, reg, params = make_publisher(tmp_path, rng, steps=2)
    opt = {"m": np.zeros(8, np.float32)}
    mgr.registry = None                      # publishing stops here
    for step in (2, 3):
        params = dict(params, w=params["w"].copy())
        params["w"][:64] = rng.standard_normal(64)
        mgr.save(step, params, opt)
    assert reg.read_index(mgr.image).head == tag(1)     # stale
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"),
                             image=mgr.image, keep=12, registry=reg)
    upd = fol.poll()
    assert upd is not None and upd.step == 3
    assert np.array_equal(np.asarray(upd.params["w"]), params["w"])


def test_follower_requires_some_channel(tmp_path):
    with pytest.raises(ValueError):
        CheckpointFollower(None, str(tmp_path / "serve"))
