"""MoE sort-based capacity dispatch vs dense per-expert oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (dispatch_indices, moe_ffn, moe_ffn_reference,
                              route)


def weights(key, E, d, f):
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (d, E)) * 0.1,
            jax.random.normal(ks[1], (E, d, f)) * d ** -0.5,
            jax.random.normal(ks[2], (E, d, f)) * d ** -0.5,
            jax.random.normal(ks[3], (E, f, d)) * f ** -0.5)


@pytest.mark.parametrize("T,d,E,k,f", [(64, 16, 4, 2, 32), (128, 8, 8, 1, 16),
                                       (96, 32, 8, 8, 8)])
def test_moe_matches_dense_reference(T, d, E, k, f):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, d))
    wr, wg, wu, wd = weights(key, E, d, f)
    # capacity_factor big enough that nothing drops
    out, aux = moe_ffn(x, wr, wg, wu, wd, top_k=k, capacity_factor=float(E))
    ref, aux_ref = moe_ffn_reference(x, wr, wg, wu, wd, top_k=k)
    assert np.abs(np.asarray(out - ref)).max() < 1e-4
    assert abs(float(aux) - float(aux_ref)) < 1e-5


def test_dispatch_capacity_drops():
    """Over-capacity tokens must be dropped, never mis-routed."""
    experts = jnp.array([[0], [0], [0], [1]])       # 3 tokens to expert 0
    slot, keep, token = dispatch_indices(experts, n_experts=2, capacity=2)
    kept_e0 = int(jnp.sum(keep & (slot // 2 == 0)))
    assert kept_e0 == 2                              # capacity enforced
    assert bool(keep[3])                             # expert 1 kept


def test_router_weights_normalized():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (32, 16))
    wr = jax.random.normal(key, (16, 4))
    gate, experts, aux = route(x, wr, top_k=2)
    assert np.allclose(np.asarray(gate.sum(-1)), 1.0, atol=1e-5)
    # E * sum(f*p) = 1 holds exactly only when the dispatch fraction f
    # equals the softmax mass p; with f counted from hard top-k
    # assignments the two distributions skew apart slightly, so the aux
    # loss can dip a few percent below 1 for a random router.
    assert float(aux) >= 0.95    # near the balanced value of 1



def test_moe_drop_degrades_gracefully():
    """With tight capacity the output is still finite and close-ish."""
    key = jax.random.PRNGKey(2)
    T, d, E, k, f = 128, 16, 4, 2, 32
    x = jax.random.normal(key, (T, d))
    wr, wg, wu, wd = weights(key, E, d, f)
    out, _ = moe_ffn(x, wr, wg, wu, wd, top_k=k, capacity_factor=1.0)
    assert np.all(np.isfinite(np.asarray(out)))
