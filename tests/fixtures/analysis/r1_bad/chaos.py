"""Chaos matrix that lost a seam and kept a dead spec."""
from ft.faults import FaultSpec

SEAMS = ("wire.send",)


def cell(seed: int) -> FaultSpec:
    return FaultSpec(point="ghost.point", mode="drop")
