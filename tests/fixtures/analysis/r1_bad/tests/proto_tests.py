def test_send_converges() -> None:
    assert "wire.send"
