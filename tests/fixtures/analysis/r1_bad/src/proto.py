"""R1 violating fixture: ``wire.recv`` is injected in src but named by
neither the chaos matrix nor any test, and the chaos module specs a
``ghost.point`` that exists nowhere in src."""
from ft.faults import fault_point


def send(key: str) -> None:
    fault_point("wire.send", key)


def recv(key: str) -> None:
    fault_point("wire.recv", key)
