"""R5 clean twin: holdings updated with the tag state, under the lock."""


class LayerStore:
    def remove_tag(self, name: str, tag: str) -> None:
        self._tags_cache.pop(name, None)
        self._holdings_apply_remove(name, tag)

    def note_holding(self, h: str, tag: str) -> None:
        with self._holdings_lock:
            self._holdings_cache[h] = tag
