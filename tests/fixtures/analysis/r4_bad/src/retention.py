"""R4 violating fixture: retention fires without consulting leases and
without an explicit force= override."""


def cleanup(store, image: str) -> None:
    store.remove_image(image, "stale")
