"""R4 clean twin: lease-consulting and explicit-force retention."""


def cleanup(store, image: str) -> None:
    if store.lease_holders(image):
        return
    store.remove_image(image, "stale")


def force_cleanup(store, image: str) -> None:
    store.remove_image(image, "stale", force=True)
