"""R2 violating fixture: a broad except over a fault-point-reaching body
that neither re-raises nor is CrashInjected-guarded — a simulated
SIGKILL would be swallowed."""
from ft.faults import fault_point


def pull(key: str):
    try:
        return fault_point("seam.pull", key)
    except Exception:
        return None
