"""R1 clean twin: both points appear in the chaos matrix and in tests,
and every spec targets a real point."""
from ft.faults import fault_point


def send(key: str) -> None:
    fault_point("wire.send", key)


def recv(key: str) -> None:
    fault_point("wire.recv", key)
