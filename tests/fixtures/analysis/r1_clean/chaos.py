from ft.faults import FaultSpec

SEAMS = ("wire.send", "wire.recv")


def cell(seed: int) -> FaultSpec:
    return FaultSpec(point="wire.send", mode="drop")
