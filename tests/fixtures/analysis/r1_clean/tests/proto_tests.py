def test_both_seams_converge() -> None:
    assert "wire.send" and "wire.recv"
