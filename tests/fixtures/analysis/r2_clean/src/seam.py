"""R2 clean twin: the same seam, crash-guarded; plus a re-raising
bookkeeping handler (both compliant shapes)."""
from ft.faults import CrashInjected, fault_point


def pull(key: str):
    try:
        return fault_point("seam.pull", key)
    except CrashInjected:
        raise
    except Exception:
        return None


def push(key: str):
    try:
        return fault_point("seam.push", key)
    except Exception:
        raise
