"""R5 violating fixture: a tag-cache invalidation that skips the
holdings index, and a holdings write outside the lock."""


class LayerStore:
    def remove_tag(self, name: str, tag: str) -> None:
        self._tags_cache.pop(name, None)

    def note_holding(self, h: str, tag: str) -> None:
        self._holdings_cache[h] = tag
