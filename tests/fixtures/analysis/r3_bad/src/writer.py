"""R3 violating fixture: commit-point rename with no durability scope —
bytes can still be in the page cache when the new name appears."""
import os


def publish(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)
