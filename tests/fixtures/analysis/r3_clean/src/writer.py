"""R3 clean twin: fsync before the rename dominates the commit point."""
import os


def publish(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
