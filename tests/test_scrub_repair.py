"""Self-healing loop: scrub detection, anti-entropy repair, gc/lease
safety around an in-flight RepairSession, SIGKILL-mid-repair crash
consistency, the follower's pre-swap verify gate, Engine rollback, and
the chaos matrix's bitrot cells."""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, RepairFailed,
                        RepairSession, export_delta, push_delta,
                        repair_image)
from repro.ft.faults import FaultSpec, inject, inject_bitrot
from repro.ft.scrub import N_SHARDS, ScrubReport, load_cursor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "params", "content"),
    Instruction("RUN", "opt_init", "content"),
    Instruction("CMD", "serve", "config"),
]


def mk_store(tmp_path, name="store", chunk=512):
    return LayerStore(str(tmp_path / name), chunk_bytes=chunk)


def payloads(rng, scale=1.0):
    return {
        "params": {"w0": (rng.standard_normal(2000) * scale)
                   .astype(np.float32),
                   "w1": rng.standard_normal(1000).astype(np.float32)},
        "opt_init": {"m": np.zeros(500, np.float32)},
    }


def providers(p):
    return {k: (lambda v=v: v) for k, v in p.items()}


def build(store, rng, name="m", tag="v1", scale=1.0):
    p = payloads(rng, scale)
    store.build_image(name, tag, INS, providers(p))
    return p


def chunkset(store, name, tag):
    m, _ = store.read_image(name, tag)
    return [h for lid in m.layer_ids
            for rec in store.read_layer(lid).records
            for h in rec.chunks]


def blob_snapshot(store):
    out = {}
    for dirp, _, files in os.walk(os.path.join(store.root, "blobs")):
        for f in files:
            with open(os.path.join(dirp, f), "rb") as fh:
                out[f] = fh.read()
    return out


# ------------------------------------------------------------------ scrub
def test_scrub_clean_store_no_findings(tmp_path, rng):
    store = mk_store(tmp_path)
    build(store, rng)
    rep = store.scrub()
    assert rep.complete and rep.clean
    assert rep.blobs_scanned > 0 and rep.bytes_scanned > 0
    assert rep.layers_scanned == 4 and rep.images_scanned == 1


def test_scrub_detects_every_flip_with_attribution(tmp_path, rng):
    """100% detection, zero false positives, findings attributed to the
    committed image that references the rotten blob."""
    store = mk_store(tmp_path)
    build(store, rng)
    flips = inject_bitrot(store.root, seed=3, count=3)
    assert len(flips) == 3
    rep = store.scrub()
    assert set(rep.corrupt_blob_hashes) == {h for h, _ in flips}
    for f in rep.corruptions:
        assert f.kind == "corrupt_blob"
        assert f.image == "m" and f.tag == "v1" and f.layer_id


def test_scrub_missing_blob_and_orphans(tmp_path, rng):
    store = mk_store(tmp_path)
    build(store, rng)
    lost = chunkset(store, "m", "v1")[0]
    os.remove(store._blob_path(lost))
    # plant debris: an unreferenced blob and an orphan descriptor —
    # flushed, because a blob still in the open batch transaction is
    # in-flight state the scrub rightly skips
    store.write_blob("ab" + "0" * 62, b"debris")
    store.sync_for_commit()
    orphan_lid = "c" * 32
    with open(store._layer_path(orphan_lid), "wb") as f:
        f.write(b"{}")
    rep = store.scrub()
    kinds = sorted(f.kind for f in rep.findings)
    assert kinds == ["missing_blob", "orphan_blob", "orphan_layer"]
    assert rep.corrupt_blob_hashes == [lost]
    assert not rep.clean and rep.complete


def test_scrub_corrupt_descriptor_and_config_lock(tmp_path, rng):
    store = mk_store(tmp_path)
    build(store, rng)
    m, _ = store.read_image("m", "v1")
    lp = store._layer_path(m.layer_ids[1])
    raw = open(lp, "rb").read()
    with open(lp, "wb") as f:                  # truncate: unreadable JSON
        f.write(raw[:len(raw) // 2])
    store._layer_cache.clear()
    rep = store.scrub()
    assert [f.kind for f in rep.corruptions] == ["layer_unreadable"]
    assert rep.corruptions[0].layer_id == m.layer_ids[1]


def test_scrub_sliced_pass_resumes_and_unions_to_full(tmp_path, rng):
    store = mk_store(tmp_path)
    build(store, rng)
    flips = inject_bitrot(store.root, seed=7, count=2)
    full = store.scrub()
    store.scrub(reset=True)                    # discard that pass's cursor
    total, slices = ScrubReport(), 0
    while True:
        part = store.scrub(max_items=2)
        assert part.blobs_scanned >= 1         # every slice makes progress
        total.merge(part)
        slices += 1
        if part.complete:
            break
        # the persisted cursor is what makes the pass resumable
        assert load_cursor(store.root) == part.next_shard > 0
        assert slices <= N_SHARDS + 4
    assert slices > 1
    assert total.complete and load_cursor(store.root) == 0
    assert total.corrupt_blob_hashes == full.corrupt_blob_hashes == \
        sorted(h for h, _ in flips)
    assert total.blobs_scanned == full.blobs_scanned


def test_scrub_skips_quarantine_and_inflight(tmp_path, rng):
    store = mk_store(tmp_path)
    build(store, rng)
    victim = chunkset(store, "m", "v1")[0]
    inject_bitrot(store.root, seed=1, count=1, candidates=[victim])
    assert store.quarantine_blob(victim)
    rep = store.scrub()
    # the quarantined copy is out of the namespace: the finding is now
    # "missing", never "corrupt", and the quarantine dir isn't walked
    assert [f.kind for f in rep.corruptions] == ["missing_blob"]
    assert store.quarantined_blobs() == [victim]


# --------------------------------------------------- incremental holdings
def test_holdings_incremental_equals_rebuild(tmp_path, rng):
    """Property-style: after any seeded interleaving of builds and
    removals, the incrementally-maintained index equals a cold rebuild by
    a SECOND store instance over the same root (``fresh=True`` on the
    same instance would replace the cache under test)."""
    for seed in (0, 1, 2):
        r = np.random.default_rng(seed)
        store = mk_store(tmp_path, name=f"hold{seed}")
        live = []
        for step in range(14):
            if live and r.random() < 0.35:
                name, tag = live.pop(int(r.integers(len(live))))
                store.remove_image(name, tag)
            else:
                name = f"img{int(r.integers(3))}"
                tag = f"t{step}"
                p = payloads(r, scale=float(r.integers(1, 4)))
                store.build_image(name, tag, INS, providers(p))
                live.append((name, tag))
            for window in (2, 8):
                got = store.holdings_index(tag_window=window)
                want = LayerStore(store.root, chunk_bytes=512) \
                    .holdings_index(tag_window=window)
                assert got.committed_layers == want.committed_layers
                assert got.by_family == want.by_family
                assert got.known_chunks == want.known_chunks
                assert got.images == want.images


def test_holdings_cache_falls_back_to_rebuild_on_stale(tmp_path, rng):
    """An update the incremental path can't apply exactly (overwriting an
    existing tag) drops the cached window; the next read rebuilds."""
    store = mk_store(tmp_path)
    build(store, rng)
    store.holdings_index(tag_window=8)
    build(store, rng, tag="v1", scale=2.0)     # tag overwrite
    got = store.holdings_index(tag_window=8)
    want = LayerStore(store.root, chunk_bytes=512).holdings_index(
        tag_window=8)
    assert got.by_family == want.by_family
    assert got.known_chunks == want.known_chunks


# ----------------------------------------------------------------- repair
def test_repair_pulls_only_damaged_bytes_counter_proved(tmp_path, rng):
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    good = blob_snapshot(dst)
    flips = inject_bitrot(dst.root, seed=5, count=3)
    damaged = {h for h, _ in flips}
    rep = dst.scrub()

    reads = []
    orig = src.read_blob
    src.read_blob = lambda h: (reads.append(h), orig(h))[1]
    rr = repair_image(dst, "m", "v1", peers=[src], scrub_report=rep)
    del src.read_blob

    assert rr.verified_clean and rr.repaired_blobs == 3
    assert set(reads) == damaged               # ONLY the damaged blobs
    assert rr.wire_amplification <= 1.25
    assert blob_snapshot(dst) == good          # bit-identical restore
    assert set(dst.quarantined_blobs()) == damaged
    assert dst.verify_image("m", "v1", deep=True) == []


def test_repair_without_scrub_report_plans_itself(tmp_path, rng):
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    inject_bitrot(dst.root, seed=9, count=2)
    rr = repair_image(dst, "m", "v1", peers=[src])
    assert rr.verified_clean and rr.repaired_blobs == 2
    assert dst.scrub().clean


def test_repair_from_offline_bundle_peer(tmp_path, rng):
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    good = blob_snapshot(dst)
    bundle_bytes = export_delta(src, "m", "v1")
    inject_bitrot(dst.root, seed=4, count=2)
    rr = repair_image(dst, "m", "v1", peers=[bundle_bytes])
    assert rr.verified_clean
    assert blob_snapshot(dst) == good


def test_repair_any_peer_fallback_skips_rotten_copies(tmp_path, rng):
    """A peer whose own copy is ALSO rotten is skipped per blob; the next
    peer sources it — any-peer anti-entropy."""
    src = mk_store(tmp_path, "src")
    build(src, rng)
    sick_peer = mk_store(tmp_path, "sick")
    dst = mk_store(tmp_path, "dst")
    push_delta(src, sick_peer, "m", "v1")
    push_delta(src, dst, "m", "v1")
    flips = inject_bitrot(dst.root, seed=6, count=2)
    damaged = sorted(h for h, _ in flips)
    # the first peer's copies of the SAME blobs are rotten too
    inject_bitrot(sick_peer.root, seed=1, count=2, candidates=damaged)
    rr = repair_image(dst, "m", "v1", peers=[sick_peer, src])
    assert rr.verified_clean
    # both sick copies were pulled, discarded on re-hash, re-pulled good
    assert rr.bytes_pulled > rr.damaged_bytes
    assert all(rr.peer_used[h] == src.root for h in damaged)


def test_repair_unsourceable_raises_and_force_overrides(tmp_path, rng):
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    flips = inject_bitrot(dst.root, seed=8, count=2)
    empty = mk_store(tmp_path, "empty")
    with pytest.raises(RepairFailed) as ei:
        repair_image(dst, "m", "v1", peers=[empty])
    assert sorted(ei.value.report.unsourced) == \
        sorted(h for h, _ in flips)
    # the bad bytes are OUT of the namespace either way: visibly
    # incomplete, never silently corrupt
    assert set(dst.quarantined_blobs()) == {h for h, _ in flips}
    problems = dst.verify_image("m", "v1", deep=True)
    assert problems and all("missing" in p for p in problems)
    rr = repair_image(dst, "m", "v1", peers=[empty], force=True)
    assert not rr.verified_clean and len(rr.unsourced) == 2
    # a later retry against a healthy peer converges
    assert repair_image(dst, "m", "v1", peers=[src]).verified_clean


def test_repair_refetches_corrupt_descriptor_under_config_lock(tmp_path,
                                                               rng):
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    good = blob_snapshot(dst)
    m, _ = dst.read_image("m", "v1")
    lp = dst._layer_path(m.layer_ids[1])
    raw = open(lp, "rb").read()
    with open(lp, "wb") as f:
        f.write(raw[:len(raw) // 2] + b"X" + raw[len(raw) // 2 + 1:])
    dst._layer_cache.clear()
    rep = dst.scrub()
    rr = repair_image(dst, "m", "v1", peers=[src], scrub_report=rep)
    assert rr.repaired_layers == 1 and rr.verified_clean
    assert blob_snapshot(dst) == good


def test_repair_rejects_descriptor_diverging_from_config_lock(tmp_path,
                                                              rng):
    """The local committed config is the trust anchor: a peer cannot swap
    in a descriptor the config never vouched for."""
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    m, _ = dst.read_image("m", "v1")
    lid = m.layer_ids[1]
    os.remove(dst._layer_path(lid))
    dst._layer_cache.clear()
    # the peer serves a VALID but different descriptor under the same id
    evil = mk_store(tmp_path, "evil")
    build(evil, np.random.default_rng(99), scale=3.0)
    em, _ = evil.read_image("m", "v1")
    forged = evil.read_layer(em.layer_ids[1], use_cache=False)
    object.__setattr__(forged, "layer_id", lid) if False else None
    forged.layer_id = lid
    evil.write_layer(forged)
    evil._layer_cache.clear()

    class _Evil:
        store = evil
    with pytest.raises(RepairFailed) as ei:
        repair_image(dst, "m", "v1", peers=[_Evil()])
    assert f"layer:{lid}" in ei.value.report.unsourced
    assert repair_image(dst, "m", "v1", peers=[src]).verified_clean


# ----------------------------------------------------- gc vs repair races
def test_gc_does_not_sweep_blobs_pinned_by_repair_session(tmp_path, rng):
    """A corrupt descriptor makes gc's mark phase under-count (it cannot
    read the chunk list), so without the session's pin the damaged
    layer's GOOD sibling blobs would be swept mid-repair."""
    src = mk_store(tmp_path, "src")
    build(src, rng)
    dst = mk_store(tmp_path, "dst")
    push_delta(src, dst, "m", "v1")
    good = blob_snapshot(dst)
    m, _ = dst.read_image("m", "v1")
    lp = dst._layer_path(m.layer_ids[1])
    with open(lp, "wb") as f:
        f.write(b"not json")
    dst._layer_cache.clear()

    session = RepairSession(dst, "m", "v1", peers=[src]).plan()
    assert session.damaged_layers == [m.layer_ids[1]]
    swept = dst.gc()                     # concurrent retention pass
    assert swept["blobs_swept"] == 0, \
        "gc swept blobs pinned by the session"
    # the lease the session holds also blocks tag removal mid-repair
    assert dst.leased("m", "v1")
    assert not dst.remove_image("m", "v1")
    rr = session.run()
    assert rr.verified_clean
    assert blob_snapshot(dst) == good
    assert not dst.leased("m", "v1")     # released with the session
    # with the pin gone, gc still sweeps nothing (all referenced again)
    assert dst.gc()["blobs_swept"] == 0


def test_scrub_concurrent_with_gc_stays_quiet(tmp_path, rng):
    """A scrub slice interleaved with gc over a healthy store must not
    produce findings (gc removes only unreferenced files; scrub flags
    only referenced ones)."""
    store = mk_store(tmp_path)
    build(store, rng)
    build(store, rng, tag="v2", scale=2.0)
    store.remove_image("m", "v1")
    total = ScrubReport()
    while True:
        part = store.scrub(max_items=2)
        total.merge(part)
        store.gc()                       # sweep between every slice
        if part.complete:
            break
    assert total.corruptions == []


# ------------------------------------------------------ SIGKILL mid-repair
def _kill9_repair(tmp_path, kill_point):
    root = str(tmp_path)
    script = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.core import Instruction, LayerStore, push_delta
        import repro.core.registry as registry
        from repro.core import repair_image
        from repro.ft.faults import inject_bitrot

        ins = [Instruction("FROM", "base", "config"),
               Instruction("COPY", "params", "content"),
               Instruction("CMD", "serve", "config")]
        root = {root!r}
        src = LayerStore(os.path.join(root, "src"), chunk_bytes=512)
        src.build_image("m", "v1", ins,
                        {{"params": lambda: {{"w": np.arange(
                            3000, dtype=np.float32)}}}})
        dst = LayerStore(os.path.join(root, "dst"), chunk_bytes=512)
        push_delta(src, dst, "m", "v1")
        flips = inject_bitrot(dst.root, seed=2, count=2)
        with open(os.path.join(root, "flips.txt"), "w") as f:
            f.write("\\n".join(h for h, _ in flips))
        orig_fp = registry.fault_point
        def dying_fp(point, key="", data=None):
            if point == {kill_point!r}:
                os.kill(os.getpid(), signal.SIGKILL)
            return orig_fp(point, key, data)
        registry.fault_point = dying_fp
        print("READY", flush=True)
        repair_image(dst, "m", "v1", peers=[src])
        print("UNREACHABLE", flush=True)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env,
                       timeout=120)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr)
    assert "READY" in r.stdout and "UNREACHABLE" not in r.stdout
    with open(os.path.join(root, "flips.txt")) as f:
        return set(f.read().split())


@pytest.mark.parametrize("kill_point", ["repair.pull", "repair.commit"])
def test_kill9_mid_repair_no_worse_then_retry_converges(tmp_path,
                                                        kill_point):
    """SIGKILL during the pull (quarantines done, swap-ins not) and at
    the commit probe (swap-ins done, flush not): either way the store is
    no worse than before — corrupt blobs are in quarantine, nothing torn
    was swapped in — and a clean retry converges to deep-verified."""
    flipped = _kill9_repair(tmp_path, kill_point)
    src = LayerStore(str(tmp_path / "src"), chunk_bytes=512)
    dst = LayerStore(str(tmp_path / "dst"), chunk_bytes=512)
    # invariant: visibly-incomplete at worst — NO corrupt blob remains
    # addressable (quarantine happened before any pull), and whatever WAS
    # swapped back in re-hashes clean
    rep = dst.scrub()
    assert {f.kind for f in rep.corruptions} <= {"missing_blob"}
    assert flipped <= set(dst.quarantined_blobs()) | \
        {f.blob for f in rep.findings} | set()
    assert set(dst.quarantined_blobs()) == flipped
    rr = repair_image(dst, "m", "v1", peers=[src])
    assert rr.verified_clean
    assert dst.verify_image("m", "v1", deep=True) == []
    assert dst.scrub().clean


# --------------------------------------------- follower gate + engine
def _ckpt_fixture(tmp_path, rng):
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve.engine import CheckpointFollower
    params = {"w": rng.standard_normal(2000).astype(np.float32)}
    opt = {"m": np.zeros(500, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"), keep=3)
    assert fol.poll().step == 0
    return mgr, fol, params, opt


def test_follower_gate_heals_persisted_bitrot_in_line(tmp_path, rng):
    mgr, fol, params, opt = _ckpt_fixture(tmp_path, rng)
    params2 = {"w": params["w"] + 1.0}
    mgr.save(1, params2, opt)
    with inject(11, FaultSpec(point="store.write_blob", mode="bitrot",
                              match=fol.local.root, times=1)) as inj:
        upd = fol.poll()
    assert inj.fired() >= 1
    assert upd is not None and upd.step == 1
    h = fol.health()
    assert h.corrupt_polls == 1 and h.repairs == 1
    # the healed local revision is bit-identical to the trainer's
    tag = "step-00000001"
    assert fol.local.verify_image(fol.image, tag, deep=True) == []
    flat = fol.local.load_image_payload(fol.image, tag)
    assert np.array_equal(flat["params/w"], params2["w"])


def test_follower_unhealable_keeps_last_step_and_retries(tmp_path, rng):
    mgr, fol, params, opt = _ckpt_fixture(tmp_path, rng)
    params2 = {"w": params["w"] + 1.0}
    mgr.save(1, params2, opt)
    with inject(13, FaultSpec(point="store.write_blob", mode="bitrot",
                              match=fol.local.root, times=1),
                FaultSpec(point="repair.pull", mode="drop", times=None)):
        upd = fol.poll()
    assert upd is None and fol.last_step == 0       # kept last-known-good
    h = fol.health()
    assert h.corrupt_polls == 1 and h.last_verify_error
    assert h.consecutive_failures == 0              # degraded, not sick
    upd = fol.poll()                                # faults gone: self-heal
    assert upd is not None and upd.step == 1
    assert fol.local.verify_image(fol.image, "step-00000001",
                                  deep=True) == []


def test_engine_rollback_restores_bit_identical_params(rng):
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.serve.engine import Engine
    cfg = get_smoke_config("yi-6b")
    from repro.models import init_params
    p1 = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, p1, max_len=32)
    assert not eng.rollback()                       # no history yet
    eng.refresh(p1, step=1)
    want = [np.asarray(x) for x in jax.tree.leaves(p1)]
    p2 = jax.tree.map(lambda x: x + 1.0, p1)
    eng.refresh(p2, step=2)
    assert eng.rollback()
    got = [np.asarray(x) for x in jax.tree.leaves(eng.params)]
    assert all(np.array_equal(a, b) for a, b in zip(got, want))
    h = eng.health()
    assert h.rollbacks == 1 and h.last_rollback_step == 1
    assert not eng.rollback()                       # history is one deep


def test_poll_and_refresh_rolls_back_on_mid_swap_failure(tmp_path, rng):
    jax = pytest.importorskip("jax")
    mgr, fol, params, opt = _ckpt_fixture(tmp_path, rng)
    from repro.configs import get_smoke_config
    from repro.serve.engine import Engine
    cfg = get_smoke_config("yi-6b")
    from repro.models import init_params
    live = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, live, max_len=32)
    eng.refresh(live, step=0)
    want = [np.asarray(x) for x in jax.tree.leaves(live)]
    mgr.save(1, {"w": params["w"] + 1.0}, opt)
    # the checkpoint's tree doesn't match the live engine's: the sparse /
    # full swap applies, but a stale sparse plan would raise — simulate a
    # mid-swap death via a poisoned refresh
    orig_refresh = eng.refresh

    def dying_refresh(*a, **k):
        orig_refresh(*a, **k)
        raise RuntimeError("device OOM mid-swap")
    eng.refresh = dying_refresh
    upd = fol.poll_and_refresh(eng)
    eng.refresh = orig_refresh
    assert upd is None
    assert "rolled back" in (fol.last_verify_error or "")
    got = [np.asarray(x) for x in jax.tree.leaves(eng.params)]
    assert all(np.array_equal(a, b) for a, b in zip(got, want))
    assert eng.health().rollbacks == 1


# ------------------------------------------------------------ chaos cells
@pytest.mark.parametrize("scenario", ["push", "fanout", "relay",
                                      "follower"])
def test_chaos_bitrot_cell_converges(tmp_path, scenario):
    from repro.ft.chaos import run_cell
    cell = run_cell(scenario, "bitrot", seed=0, base_dir=tmp_path)
    assert cell.ok and cell.fired >= 1
