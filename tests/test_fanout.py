"""Fan-out delta replication + delta-aware serving refresh: one negotiated
have-set and one source read pass for N replicas, per-replica failure
isolation with converging retries, SIGKILL atomicity across the fleet, and
the sparse CheckpointFollower/Engine.refresh path (partial refresh must be
bit-identical to a full reload)."""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, PushRejected,
                        diff_tensor_records, inject_payload_update,
                        push_delta, replicate_fanout)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "src", "content"),
    Instruction("RUN", "deps", "content"),            # independent of src
    Instruction("CMD", "run", "config"),
]


def mk(tmp_path, name):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


def make_payloads(rng):
    return {
        "src": {"a.py": rng.standard_normal(1000).astype(np.float32),
                "b.py": rng.standard_normal(500).astype(np.float32)},
        "deps": {"lib": rng.standard_normal(4000).astype(np.float32)},
    }


def build_v1(store, payloads):
    prov = {k: (lambda v=v: v) for k, v in payloads.items()}
    store.build_image("app", "v1", INS, prov)


def inject_v2(store, payloads):
    src2 = {k: v.copy() for k, v in payloads["src"].items()}
    src2["b.py"][3] = 42.0                        # ONE changed 512 B chunk
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"deps": lambda: payloads["deps"]})
    return src2


def snapshot(store, name, tag):
    manifest, config = store.read_image(name, tag)
    layers, blobs = {}, {}
    for lid in manifest.layer_ids:
        with open(store._layer_path(lid), "rb") as f:
            layers[lid] = f.read()
        for rec in store.read_layer(lid).records:
            for h in rec.chunks:
                blobs[h] = store.read_blob(h)
    return {"manifest": manifest.to_json(), "config": config.to_json(),
            "layers": layers, "blobs": blobs}


def count_reads(store):
    """Shadow ``read_blob`` with a counting wrapper (independent proof of
    FanoutStats.source_blob_reads)."""
    counter = {"n": 0}
    orig = store.read_blob

    def counting(h):
        counter["n"] += 1
        return orig(h)

    store.read_blob = counting
    return counter


# ---------------------------------------------------------------- fan-out
def test_fanout_bit_identical_to_push_delta(tmp_path, rng):
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    replicas = [mk(tmp_path, f"r{i}") for i in range(3)]
    single = mk(tmp_path, "single")
    for tag in ("v1", "v2"):
        fan = replicate_fanout(store, replicas, "app", tag)
        assert fan.ok and fan.n_ok == 3
        push_delta(store, single, "app", tag)
        want = snapshot(single, "app", tag)
        for r in replicas:
            assert snapshot(r, "app", tag) == want
            assert r.verify_image("app", tag, deep=True) == []


def test_fanout_one_round_one_read_pass_mixed_staleness(tmp_path, rng):
    """Replicas at DIFFERENT states: one holds v1 (missing only the delta),
    one is empty (missing everything), one already holds v2. One
    negotiation round; each blob read from the source exactly once —
    counter-proved — and per-replica send lists carved from that pass."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    warm, cold, done = (mk(tmp_path, n) for n in ("warm", "cold", "done"))
    push_delta(store, warm, "app", "v1")
    push_delta(store, done, "app", "v2")

    counter = count_reads(store)
    fan = replicate_fanout(store, [warm, cold, done], "app", "v2")
    assert fan.ok
    assert fan.negotiation_rounds == 1
    # union of missing blobs == what the cold replica needs (superset of
    # warm's one changed chunk; done needs nothing)
    assert fan.source_blob_reads == fan.blobs_broadcast == counter["n"]
    s_warm, s_cold, s_done = (r.stats for r in fan.replicas)
    assert s_warm.blobs_sent == 1            # just the changed chunk
    assert s_warm.bytes_payload == 512
    assert s_cold.blobs_sent == counter["n"]  # everything, from SAME reads
    assert s_done.blobs_sent == 0
    assert s_done.layers_dedup > 0
    for r, stats in zip((warm, cold, done), (s_warm, s_cold, s_done)):
        assert r.verify_image("app", "v2", deep=True) == []
        assert stats.bytes_sent == stats.bytes_payload + stats.bytes_meta
    # wire amplification per replica stays O(what THAT replica lacked):
    # warm's wire is the changed chunk + metadata, far below cold's
    assert s_warm.bytes_sent < s_cold.bytes_sent / 2


def test_fanout_failed_replica_isolated_and_retry_converges(tmp_path, rng):
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    replicas = [mk(tmp_path, f"r{i}") for i in range(3)]
    fan = replicate_fanout(store, replicas, "app", "v1")
    assert fan.ok
    inject_v2(store, payloads)

    class Boom(RuntimeError):
        pass

    def dying_write_layer(layer, encoded=None):
        raise Boom("disk full")

    replicas[1].write_layer = dying_write_layer      # instance shadow
    try:
        fan = replicate_fanout(store, replicas, "app", "v2")
    finally:
        del replicas[1].write_layer
    # the sick replica is isolated; the healthy ones committed
    assert not fan.ok and fan.n_ok == 2
    assert fan.replicas[1].error is not None
    assert isinstance(fan.replicas[1].exception, Boom)
    assert fan.replicas[1].stats is None
    for i in (0, 2):
        assert fan.replicas[i].ok
        assert replicas[i].verify_image("app", "v2", deep=True) == []
    # the failed replica kept its previous tag fully intact
    assert replicas[1].list_tags("app") == ["v1"]
    assert replicas[1].verify_image("app", "v1", deep=True) == []

    # retry converges: the failed replica catches up, the healthy ones
    # dedup everything (no payload resent to them)
    fan = replicate_fanout(store, replicas, "app", "v2")
    assert fan.ok
    assert fan.replicas[0].stats.bytes_payload == 0
    assert fan.replicas[2].stats.bytes_payload == 0
    assert replicas[1].verify_image("app", "v2", deep=True) == []


def test_fanout_mutation_gate_per_replica(tmp_path, rng):
    """A self-consistent in-place mutation at the source (same layer ids,
    re-keyed checksums/chains — the strongest naive bypass) is rejected at
    EVERY replica's negotiation gate, before a byte moves, while a replica
    that never saw the original id still accepts the image."""
    from repro.core import (BuildReport, ImageConfig, apply_edits,
                            chain_checksum, diff_layer_host, new_uuid)
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    seen = [mk(tmp_path, f"seen{i}") for i in range(2)]
    fan = replicate_fanout(store, seen, "app", "v1")
    assert fan.ok
    before = [snapshot(r, "app", "v1") for r in seen]

    # mutate src in place AND re-key the whole image so it self-verifies
    m, cfg = store.read_image("app", "v1")
    layers = [store.read_layer(lid, use_cache=False) for lid in m.layer_ids]
    src2 = {k: v.copy() for k, v in payloads["src"].items()}
    src2["b.py"][0] = 9.0
    apply_edits(store, layers[1], diff_layer_host(layers[1], src2),
                BuildReport())
    parent, checksums, chains = None, {}, {}
    for layer in layers:
        layer.chain = chain_checksum(parent, layer.checksum,
                                     layer.instruction.text)
        store.write_layer(layer)
        checksums[layer.layer_id] = layer.checksum
        chains[layer.layer_id] = layer.chain
        parent = layer.chain
    new_cfg = ImageConfig(config_id=new_uuid(), arch=cfg.arch,
                          version=cfg.version + 1, layer_checksums=checksums,
                          layer_chains=chains, history=cfg.history)
    m.config_id = new_cfg.config_id
    store.write_image(m, new_cfg)

    fresh = mk(tmp_path, "fresh")               # never held the old ids
    fan = replicate_fanout(store, seen + [fresh], "app", "v1")
    assert fan.n_ok == 1 and fan.replicas[2].ok
    for i, rep in enumerate(fan.replicas[:2]):
        assert isinstance(rep.exception, PushRejected)
        # the mutated bytes never reached the replica
        assert snapshot(seen[i], "app", "v1") == before[i]
    assert fresh.verify_image("app", "v1", deep=True) == []


def test_fanout_midwave_dropout_accounting_exact(tmp_path, rng):
    """A replica dying between _TRANSFER_BATCH waves must not inflate the
    books: blobs whose only taker died are neither read nor counted
    (``source_blob_reads == blobs_broadcast`` == the instrumented count),
    the dead replica's ``stats_partial`` records ONLY the bytes that
    actually reached it — never the waves that were skipped — and the
    converging retry pays exactly the remainder."""
    from repro.core.registry import _TRANSFER_BATCH
    n_chunks = 3 * _TRANSFER_BATCH            # several waves of delta
    store = mk(tmp_path, "src")
    ins = [Instruction("FROM", "base", "config"),
           Instruction("COPY", "src", "content"),
           Instruction("CMD", "run", "config")]
    payloads = {"src": {"w": rng.standard_normal(n_chunks * 128)
                        .astype(np.float32)}}          # 128 f32 = 512 B
    store.build_image("app", "v1", ins,
                      {k: (lambda v=v: v) for k, v in payloads.items()})
    current, lagging = mk(tmp_path, "cur"), mk(tmp_path, "lag")
    push_delta(store, current, "app", "v1")

    new = {"src": {"w": payloads["src"]["w"] + 1.0}}   # EVERY chunk moves
    inject_payload_update(store, "app", "v1", "v2", new)
    push_delta(store, current, "app", "v2")            # current needs 0

    class Boom(RuntimeError):
        pass

    calls = {"n": 0}
    orig_wb = lagging.write_blob

    def dying_write_blob(h, data):
        calls["n"] += 1
        if calls["n"] > 3:                    # dies inside wave 1
            raise Boom("replica lost mid-wave")
        return orig_wb(h, data)

    lagging.write_blob = dying_write_blob
    counter = count_reads(store)
    try:
        fan = replicate_fanout(store, [current, lagging], "app", "v2")
    finally:
        del lagging.write_blob, store.read_blob
    assert not fan.ok and fan.replicas[0].ok
    dead = fan.replicas[1]
    assert dead.stats is None                 # the PR-4 contract holds
    # reads stayed exact: only blobs actually shipped were read — the
    # waves after the drop were skipped entirely
    assert fan.source_blob_reads == fan.blobs_broadcast == counter["n"]
    assert counter["n"] < n_chunks
    # partial accounting: exactly the blobs that landed before the drop,
    # cross-checked against the replica's own store — never the skipped
    # waves' bytes
    landed = sum(1 for rec in store.read_layer(
        store.read_image("app", "v2")[0].layer_ids[1]).records
        for h in rec.chunks if lagging.has_blob(h))
    assert dead.stats_partial is not None
    assert dead.stats_partial.blobs_sent == landed < n_chunks
    assert dead.stats_partial.bytes_payload == landed * 512

    # the retry pays exactly the remainder: landed + retried == the delta
    fan = replicate_fanout(store, [current, lagging], "app", "v2")
    assert fan.ok
    retried = fan.replicas[1].stats
    assert retried.blobs_sent + landed == n_chunks
    assert retried.bytes_payload == (n_chunks - landed) * 512
    assert lagging.verify_image("app", "v2", deep=True) == []


def test_follower_poll_survives_remote_prune_mid_poll(tmp_path, rng,
                                                      monkeypatch):
    """Retention race, remote side: the trainer prunes the tag between the
    follower's ``latest_step`` and the pull. ``poll`` must return None
    (not raise) and converge on the next poll."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    import repro.serve.engine as engine_mod
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512, keep=0))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"))
    assert fol.poll().full
    params2 = dict(params, w=params["w"] + 1.0)
    mgr.save(1, params2, opt)

    real = engine_mod.replicate_fanout

    def racing_fanout(remote, receivers, image, tag, **kw):
        remote.remove_image(image, tag)       # the trainer's retention ran
        remote.gc()
        return real(remote, receivers, image, tag, **kw)

    monkeypatch.setattr(engine_mod, "replicate_fanout", racing_fanout)
    assert fol.poll() is None                 # survived, no exception
    monkeypatch.undo()
    assert fol.last_step == 0                 # nothing was consumed
    health = fol.health()                     # a clean None-poll is not a
    assert health.consecutive_failures == 0   # failure, just "up to date"
    assert health.last_success_step == 0

    params3 = dict(params, w=params["w"] + 2.0)
    mgr.save(2, params3, opt)                 # next poll converges
    upd = fol.poll()
    assert upd is not None and upd.step == 2
    assert np.array_equal(np.asarray(upd.params["w"]), params3["w"])


def test_follower_sparse_plan_survives_pruned_base_tag(tmp_path, rng):
    """Retention race, local side: the follower's last-seen revision is
    pruned out of its own store between polls. The sparse plan must
    downgrade to a FULL update (diff_tensor_records has no base to plan
    against) instead of raising."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"))
    assert fol.poll().full
    # a concurrent retention pass (another follower sharing the store, an
    # operator prune) drops the base revision AND sweeps its layers
    fol.local.remove_image("ckpt", f"step-{fol.last_step:08d}")
    fol.local.gc()
    params2 = dict(params, w=params["w"] + 1.0)
    mgr.save(1, params2, opt)
    upd = fol.poll()
    assert upd is not None and upd.full       # downgraded, not raised
    assert np.array_equal(np.asarray(upd.params["w"]), params2["w"])


def test_fanout_source_verify_failure_raises(tmp_path, rng):
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    m, _ = store.read_image("app", "v1")
    os.remove(store._layer_path(m.layer_ids[1]))     # break the source
    with pytest.raises(PushRejected):
        replicate_fanout(store, [mk(tmp_path, "r0")], "app", "v1")


def test_fanout_kill9_leaves_no_torn_replica(tmp_path):
    """SIGKILL mid-fan-out: every replica must be either fully at the old
    tag or fully at the new one — never torn — and a retry converges the
    whole fleet."""
    root = str(tmp_path)
    script = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.core import (Instruction, LayerStore,
                                inject_payload_update, replicate_fanout)

        ins = [Instruction("FROM", "base", "config"),
               Instruction("COPY", "src", "content"),
               Instruction("CMD", "run", "config")]
        payloads = {{"src": {{"w": np.arange(2000, dtype=np.float32)}}}}
        root = {root!r}
        store = LayerStore(os.path.join(root, "src"), chunk_bytes=256)
        prov = {{k: (lambda v=v: v) for k, v in payloads.items()}}
        store.build_image("app", "v1", ins, prov)
        replicas = [LayerStore(os.path.join(root, f"r{{i}}"),
                               chunk_bytes=256) for i in range(2)]
        fan = replicate_fanout(store, replicas, "app", "v1")
        assert fan.ok
        new = {{"src": {{"w": payloads["src"]["w"] + 1.0}}}}
        inject_payload_update(store, "app", "v1", "v2", new)
        print("READY", flush=True)

        # die the hard way inside replica 1's commit: blobs + descriptors
        # already landed (un-fsynced, batch durability), manifest rename
        # for THAT replica never happens
        def dying_write_image(manifest, config):
            os.kill(os.getpid(), signal.SIGKILL)
        replicas[1].write_image = dying_write_image
        replicate_fanout(store, replicas, "app", "v2")
        print("UNREACHABLE", flush=True)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr)
    assert "READY" in r.stdout
    assert "UNREACHABLE" not in r.stdout

    store = LayerStore(os.path.join(root, "src"), chunk_bytes=256)
    replicas = [LayerStore(os.path.join(root, f"r{i}"), chunk_bytes=256)
                for i in range(2)]
    # every replica is at a consistent point: old tag or new tag, every
    # visible tag fully verifiable — no torn state
    for rep in replicas:
        tags = rep.list_tags("app")
        assert "v1" in tags and set(tags) <= {"v1", "v2"}
        for tag in tags:
            assert rep.verify_image("app", tag, deep=True) == []
    # retry converges the whole fleet (orphans re-verified, never trusted)
    fan = replicate_fanout(store, replicas, "app", "v2")
    assert fan.ok
    for rep in replicas:
        assert rep.verify_image("app", "v2", deep=True) == []


# ------------------------------------------------- sparse serving refresh
def _dtype_tree(rng):
    import ml_dtypes
    return {
        "f32": rng.standard_normal((8, 16)).astype(np.float32),
        "bf16": rng.standard_normal(640).astype(ml_dtypes.bfloat16),
        "i8": rng.integers(-100, 100, 1500).astype(np.int8),
        "i32": rng.integers(-5, 5, 300).astype(np.int32),
        "flag": rng.standard_normal(520) > 0,
        "blocks": {"w0": rng.standard_normal(700).astype(np.float32),
                   "w1": rng.standard_normal(700).astype(np.float32)},
    }


def _leaves(tree, prefix=""):
    out = {}
    for k in sorted(tree):
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(tree[k], dict):
            out.update(_leaves(tree[k], path))
        else:
            out[path] = tree[k]
    return out


def _mk_engine(params):
    from repro.configs import get_smoke_config
    from repro.serve import Engine
    return Engine(get_smoke_config("yi-6b"), params, max_len=32)


def test_follower_sparse_poll_and_partial_refresh_bit_identical(tmp_path,
                                                                rng):
    """The full consumer loop: save -> sparse poll -> partial refresh. The
    partially-refreshed live tree must be bit-identical (values AND
    dtypes) to a full reload of the same step, across dtypes, while only
    the changed leaves were loaded/device-put."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    params = _dtype_tree(rng)
    opt = {"m": np.zeros(64, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"))
    upd = fol.poll()
    assert upd is not None and upd.full
    step, p0, o0 = upd                      # historical triple unpacking
    assert step == 0
    eng = _mk_engine(p0)
    assert eng.refresh(p0) == len(_leaves(p0))

    # touch a few leaves of different dtypes (one chunk each)
    params2 = {k: (dict(v) if isinstance(v, dict) else v)
               for k, v in params.items()}
    params2["f32"] = params["f32"].copy()
    params2["f32"][1, 2] += 1.0
    params2["bf16"] = params["bf16"].copy()
    params2["bf16"][5] += 1.0
    params2["i8"] = params["i8"].copy()
    params2["i8"][7] += 3
    params2["blocks"]["w1"] = params["blocks"]["w1"].copy()
    params2["blocks"]["w1"][0] -= 2.0
    mgr.save(1, params2, opt)

    upd = fol.poll()
    assert upd is not None and not upd.full
    assert upd.changed_params == {"f32", "bf16", "i8", "blocks/w1"}
    assert upd.changed_opt == set()          # only opt/__step__ moved
    # sparse load: only the changed tensors (+ the step scalar) assembled
    assert upd.tensors_loaded == len(upd.changed_params) + 1
    n = eng.refresh(upd.params, upd.changed_params)
    assert n == eng.last_refresh_leaves == 4

    # bit-identity against an independent FULL reload of step 1
    full = CheckpointFollower(mgr.store, str(tmp_path / "serve_full"),
                              sparse=False).poll()
    assert full is not None and full.full
    live, want = _leaves(eng.params), _leaves(full.params)
    assert set(live) == set(want)
    for path in want:
        got = np.asarray(live[path])
        assert got.dtype == np.asarray(want[path]).dtype, path
        assert np.array_equal(got, np.asarray(want[path])), path
    # unchanged leaves were not even copied: same objects as before
    assert live["i32"] is p0["i32"]
    assert live["blocks/w0"] is p0["blocks"]["w0"]


def test_follower_sparse_falls_back_on_structure_change(tmp_path, rng):
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"))
    assert fol.poll().full
    # adding a tensor is a structure change -> save_incremental falls back
    # to a rebuild, and the follower must fall back to a FULL update
    params2 = dict(params, extra=rng.standard_normal(40).astype(np.float32))
    mgr.save(1, params2, opt)
    upd = fol.poll()
    assert upd.full
    assert set(_leaves(upd.params)) == {"extra", "w"}
    assert np.array_equal(np.asarray(upd.params["extra"]),
                          params2["extra"])


def test_follower_health_and_retry_under_faults(tmp_path, rng):
    """The structured health snapshot: failures counted with the error
    recorded ("serving stale weights since step N"), a clean poll resets
    the run, a transient wire fault converges via the in-run retry and
    shows up in retries_spent."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    from repro.ft import FaultSpec, RetryPolicy, inject
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(
        mgr.store, str(tmp_path / "serve"),
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.001,
                          max_delay_s=0.01))
    assert fol.poll().step == 0
    h = fol.health()
    assert h.polls == 1 and h.failures == 0 and h.last_success_step == 0
    assert h.staleness_s is not None and h.staleness_s >= 0.0

    params2 = dict(params, w=params["w"] + 1.0)
    mgr.save(1, params2, opt)
    # persistent outage: every poll fails loudly, but the health record
    # now says "serving stale weights since step 0" instead of nothing
    with inject(0, FaultSpec(point="follower.pull", mode="drop",
                             times=None)):
        for _ in range(2):
            with pytest.raises(ConnectionError):
                fol.poll()
    h = fol.health()
    assert h.failures == 2 and h.consecutive_failures == 2
    assert h.last_error is not None and "FaultInjected" in h.last_error
    assert h.last_success_step == 0           # stale since step 0

    # transient wire fault: the in-run retry converges it within ONE poll
    with inject(1, FaultSpec(point="wire.negotiate", mode="drop",
                             match=fol.local.root)):
        upd = fol.poll()
    assert upd is not None and upd.step == 1
    h = fol.health()
    assert h.consecutive_failures == 0 and h.last_error is None
    assert h.last_success_step == 1 and h.retries_spent >= 1


def test_engine_health_snapshot(rng):
    params = {"w": rng.standard_normal(8).astype(np.float32)}
    eng = _mk_engine(params)
    h = eng.health()
    assert h.refreshes == 0 and h.staleness_s is None
    assert h.last_refresh_step is None
    eng.refresh(params, step=3)
    h = eng.health()
    assert h.refreshes == 1 and h.last_refresh_step == 3
    assert h.staleness_s is not None and h.staleness_s >= 0.0
    eng.refresh({"w": params["w"] + 1.0}, changed=["w"], step=4)
    h2 = eng.health()
    assert h2.refreshes == 2 and h2.last_refresh_step == 4
    assert h2.last_refresh_leaves == 1


def test_diff_tensor_records_plan(tmp_path, rng):
    """The metadata plan itself: changed chunk lists -> names; structural
    moves -> None."""
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    m1, _ = store.read_image("app", "v1")
    m2, _ = store.read_image("app", "v2")
    l1 = [store.read_layer(lid) for lid in m1.layer_ids]
    l2 = [store.read_layer(lid) for lid in m2.layer_ids]
    assert diff_tensor_records(l1, l2) == {"b.py"}
    assert diff_tensor_records(l1, l1) == set()
    # dtype move is structural
    import dataclasses
    recs = [dataclasses.replace(r, dtype="int8") for r in l2[1].records]
    l2_bad = list(l2)
    l2_bad[1] = dataclasses.replace(l2[1], records=recs)
    assert diff_tensor_records(l1, l2_bad) is None


def test_engine_partial_refresh_counts_and_strictness(rng):
    params = {"a": np.ones(4, np.float32),
              "nest": {"b": np.zeros(4, np.float32)}}
    eng = _mk_engine(params)
    assert eng.refresh(params) == 2              # full swap counts leaves
    sparse = {"nest": {"b": np.full(4, 7.0, np.float32)}}
    assert eng.refresh(sparse, {"nest/b"}) == 1
    assert np.array_equal(np.asarray(eng.params["nest"]["b"]),
                          sparse["nest"]["b"])
    assert eng.params["a"] is params["a"]        # untouched leaf shared
    # a changed path that isn't part of the live tree — missing parent OR
    # missing leaf — is a broken sparse plan: rejected, never grafted
    # (grafting would desync the pytree from the jitted signature)
    with pytest.raises(KeyError):
        eng.refresh({"ghost": {"x": np.ones(2, np.float32)}}, {"ghost/x"})
    with pytest.raises(KeyError):
        eng.refresh({"nest": {"c": np.ones(4, np.float32)}}, {"nest/c"})
    with pytest.raises(KeyError):
        eng.refresh({"w_new": np.ones(4, np.float32)}, {"w_new"})


def test_store_default_durability_is_batch(tmp_path):
    """ROADMAP satellite: batch is the store-wide default now — writes
    defer their fsyncs to the commit point."""
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    assert store.durability == "batch"
    from repro.core import sha256_hex
    data = b"y" * 256
    store.write_blob(sha256_hex(data), data)
    assert store.fsyncs == 0                     # deferred, not inline
    store.sync_for_commit()
    assert store.fsyncs == 2                     # file + its directory
    from repro.ckpt import CheckpointPolicy
    assert CheckpointPolicy().durability == "batch"
