"""Property tests for ft.retry.RetryPolicy — the backoff-schedule and
quarantine guarantees the chaos harness leans on: monotone pre-jitter
schedule, jitter bounded and deterministic under a fixed seed, deadline
containment (no sleep ever starts that the deadline can't contain), and
quarantine after EXACTLY max_attempts."""
import pytest

from conftest import max_examples
from repro.ft import RetryExhausted, RetryPolicy

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

policies = st.builds(
    RetryPolicy,
    max_attempts=st.integers(1, 8),
    base_delay_s=st.floats(0.0, 2.0, allow_nan=False),
    max_delay_s=st.floats(0.0, 10.0, allow_nan=False),
    multiplier=st.floats(1.0, 8.0, allow_nan=False),
    jitter=st.floats(0.0, 1.0, allow_nan=False),
    seed=st.integers(0, 2**32 - 1),
)


@settings(max_examples=max_examples(200), deadline=None)
@given(policy=policies, n=st.integers(0, 30))
def test_schedule_monotone_and_capped(policy, n):
    """The pre-jitter schedule never decreases with the attempt number and
    never exceeds the cap."""
    assert policy.schedule(n) <= policy.schedule(n + 1) or \
        policy.schedule(n) == policy.max_delay_s
    assert 0.0 <= policy.schedule(n) <= policy.max_delay_s
    assert policy.schedule(n + 1) >= min(policy.base_delay_s,
                                         policy.max_delay_s)


@settings(max_examples=max_examples(200), deadline=None)
@given(policy=policies, n=st.integers(0, 30))
def test_backoff_bounded_by_jitter_band(policy, n):
    """The actual (jittered) delay lives in [schedule, schedule*(1+jitter)]
    — jitter only ever ADDS bounded spread, never undercuts the schedule."""
    s, b = policy.schedule(n), policy.backoff(n)
    assert s <= b <= s * (1.0 + policy.jitter) + 1e-12


@settings(max_examples=max_examples(100), deadline=None)
@given(policy=policies)
def test_backoff_deterministic_under_seed(policy):
    """Same seed => bit-identical delay sequence (the chaos harness replay
    guarantee); a different seed with nonzero jitter on an uncapped,
    nonzero schedule almost always differs somewhere."""
    twin = RetryPolicy(**{**policy.__dict__})
    assert [policy.backoff(n) for n in range(10)] == \
        [twin.backoff(n) for n in range(10)]


@settings(max_examples=max_examples(150), deadline=None)
@given(policy=policies)
def test_quarantine_after_exactly_max_attempts(policy):
    """A function that always fails is called exactly max_attempts times,
    then quarantined — never one more, never one fewer, regardless of the
    backoff shape. (Simulated clock/sleep: no real waiting.)"""
    calls = []
    now = [0.0]

    def fn(attempt):
        calls.append(attempt)
        raise ValueError(f"boom {attempt}")

    result, health = policy.execute(
        fn, sleep=lambda s: now.__setitem__(0, now[0] + s),
        clock=lambda: now[0])
    assert result is None
    assert calls == list(range(1, policy.max_attempts + 1))
    assert health.quarantined and not health.succeeded
    assert health.attempts == policy.max_attempts
    assert health.retries == policy.max_attempts - 1
    assert len(health.errors) == policy.max_attempts


@settings(max_examples=max_examples(150), deadline=None)
@given(policy=policies, deadline_s=st.floats(0.0, 5.0, allow_nan=False),
       fail_n=st.integers(0, 10))
def test_deadline_contains_every_sleep(policy, deadline_s, fail_n):
    """With a deadline, no backoff sleep is ever STARTED that would
    overrun it: simulated total sleep stays within the deadline, and a
    deadline stop is flagged as such."""
    policy = RetryPolicy(**{**policy.__dict__, "deadline_s": deadline_s})
    now = [0.0]
    slept = []

    def sleep(s):
        slept.append(s)
        now[0] += s

    def fn(attempt):
        if attempt <= fail_n:
            raise ValueError("transient")
        return "ok"

    result, health = policy.execute(fn, sleep=sleep, clock=lambda: now[0])
    assert sum(slept) <= deadline_s + 1e-9
    assert health.backoff_total_s == sum(slept)
    if health.deadline_exceeded:
        # stopped early: the NEXT backoff would have overrun the deadline
        assert result is None and health.quarantined
        assert health.attempts < policy.max_attempts
        assert now[0] + policy.backoff(health.attempts - 1) > deadline_s


@settings(max_examples=max_examples(100), deadline=None)
@given(policy=policies, fail_n=st.integers(0, 10))
def test_succeeds_iff_failures_fit_in_budget(policy, fail_n):
    """fn failing its first ``fail_n`` calls succeeds exactly when
    fail_n < max_attempts (no deadline): success on attempt fail_n+1."""
    now = [0.0]

    def fn(attempt):
        if attempt <= fail_n:
            raise ValueError("transient")
        return attempt

    result, health = policy.execute(
        fn, sleep=lambda s: now.__setitem__(0, now[0] + s),
        clock=lambda: now[0])
    if fail_n < policy.max_attempts:
        assert health.succeeded and result == fail_n + 1
        assert health.attempts == fail_n + 1
    else:
        assert health.quarantined and result is None
        assert health.attempts == policy.max_attempts


def test_run_raises_retry_exhausted_with_health():
    policy = RetryPolicy(max_attempts=2, base_delay_s=0.0)

    def fn(attempt):
        raise ValueError("always")

    with pytest.raises(RetryExhausted) as ei:
        policy.run(fn)
    assert ei.value.health.attempts == 2
    assert "always" in ei.value.health.errors[-1]
