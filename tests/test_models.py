"""Per-architecture smoke tests (reduced configs) + model-level invariants.

For each of the 10 assigned archs: instantiate the reduced config, run one
forward/train step on CPU, assert output shapes + no NaNs; check
prefill->decode continuation matches teacher-forced decode-from-scratch
logits (serving correctness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          padded_vocab, prefill)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jnp.zeros(
            (B, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
        batch["mask"] = batch["mask"].at[:, :cfg.n_prefix_embeds].set(0.0)
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: loss_fn(cfg, q, b), has_aux=True)(p))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0.5             # ~ln(vocab) at init
    gnorms = [float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
              for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms), arch
    assert sum(gnorms) > 0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_equivalence(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        # capacity drops are batch-composition-dependent, so prefill (routes
        # T tokens jointly) and decode (routes 1) only agree exactly when
        # nothing drops — bump capacity for the equivalence check.
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    _, logits_pf = jax.jit(lambda p, t: prefill(cfg, p, t))(params, tokens)
    cache = init_cache(cfg, B, S + 4)
    dec = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for t in range(S):
        cache, logits_dec = dec(params, cache, tokens[:, t], jnp.int32(t))
    err = np.abs(np.asarray(logits_pf, np.float32) -
                 np.asarray(logits_dec[:, :cfg.vocab], np.float32)).max()
    assert err < 5e-2, f"{arch}: prefill/decode mismatch {err}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_param_count_matches_config(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    # account for vocab padding in the embedding (and tied/untied head)
    pad = padded_vocab(cfg) - cfg.vocab
    pad_elems = pad * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    assert actual - pad_elems == cfg.param_count(), arch


def test_loss_decreases_tiny_training():
    """20 steps of AdamW on a tiny dense model must reduce loss."""
    from repro.optim import AdamWConfig, apply_update, init_opt_state
    cfg = get_smoke_config("yi-6b").replace(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = init_opt_state(params)
    tokens = jax.random.randint(key, (4, 64), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((4, 64), jnp.float32)}
    acfg = AdamWConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt, _ = apply_update(acfg, params, opt, grads)
        return params, opt, loss

    losses = []
    for _ in range(20):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses
