"""Cross-image blob universe (PR 7 tentpole): the receiver's have-set
answers from EVERY committed tag of EVERY image, re-keying may point at a
sibling image's content-identical layer, ``gc()`` mark-and-sweeps across
the whole namespace, leases pin shared blobs through any reachable
manifest — and none of it weakens the trust boundary: orphans are never
vouched for by sibling commits, and the in-place-mutation gate fires even
when the diverged id was committed under a different image name."""
import os

import numpy as np
import pytest

from repro.core import (BuildReport, DeltaReceiver, ImageConfig, Instruction,
                        LayerStore, Manifest, PushRejected, RelayNode,
                        apply_edits, chain_checksum, diff_layer_host,
                        new_uuid, push_delta, replicate_fanout)
from repro.core.registry import export_delta, import_delta


def mk(tmp_path, name="store"):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


# A fine-tune-shaped image: a big shared backbone, a small per-tenant
# adapter, config layers on both ends.
TENANT_INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "backbone", "content"),
    Instruction("COPY", "adapter", "content"),
    Instruction("CMD", "serve", "config"),
]


def backbone_payload(rng):
    return {"w": rng.standard_normal(16384).astype(np.float32)}


def adapter_payload(rng, scale=1.0):
    return {"lora": (rng.standard_normal(256).astype(np.float32) * scale)}


def build_base(store, rng):
    bb, ad = backbone_payload(rng), adapter_payload(rng)
    prov = {"backbone": lambda: bb, "adapter": lambda: ad}
    store.build_image("base", "v1", TENANT_INS, prov)
    return bb, ad


def build_tenant(store, name, bb, adapter, parent=("base", "v1")):
    """Fork a tenant from the base: identical backbone (DLC cache hit ->
    SAME layer id as the base image), fresh adapter."""
    prov = {"backbone": lambda: bb, "adapter": lambda: adapter}
    return store.build_image(name, "v1", TENANT_INS, prov, parent=parent)


def image_chunks(store, name, tag="v1"):
    m, _ = store.read_image(name, tag)
    out = set()
    for lid in m.layer_ids:
        for rec in store.read_layer(lid).records:
            out.update(rec.chunks)
    return out


def image_meta(store, name, tag="v1"):
    m, _ = store.read_image(name, tag)
    return m, {lid: (store.read_layer(lid).family,
                     store.read_layer(lid).checksum)
               for lid in m.layer_ids}


def instrument_reads(store):
    """Shadow read_blob with a counting wrapper; returns the log list."""
    reads, orig = [], store.read_blob
    store.read_blob = lambda h: (reads.append(h), orig(h))[1]
    return reads


# ------------------------------------------------------- sibling vouching
def test_sibling_image_vouches_base_blobs(tmp_path, rng):
    """Pushing a fresh fine-tune to a remote that holds only the BASE
    image must ship only the adapter delta: the backbone layer id is held
    via the sibling image's committed manifest, and zero backbone blobs
    are even read at the source (counter-proof)."""
    src = mk(tmp_path)
    bb, _ = build_base(src, rng)
    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")

    _, _, rep = build_tenant(src, "tenant", bb, adapter_payload(rng, 3.0))
    assert rep.layers_cached >= 2          # FROM + backbone share base ids

    adapter_only = image_chunks(src, "tenant") - image_chunks(src, "base")
    assert adapter_only                    # the fork did change something
    reads = instrument_reads(src)
    stats = push_delta(src, remote, "tenant", "v1")

    assert set(reads) <= adapter_only      # zero base-blob reads
    assert stats.blobs_sent == len(adapter_only)
    assert stats.layers_dedup >= 2         # vouched by the sibling image
    assert remote.verify_image("tenant", "v1", deep=True) == []
    assert remote.verify_image("base", "v1", deep=True) == []


def test_rekey_twin_across_images_zero_blob_push(tmp_path, rng):
    """A tenant whose adapter CONTENT equals the base's but was rebuilt
    under a new layer id (instruction text changed -> DLC rule 2 rebuild)
    re-keys against the sibling image's layer: verified by checksum only,
    no blobs cross the wire."""
    src = mk(tmp_path)
    bb, ad = build_base(src, rng)
    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")

    ins = list(TENANT_INS)
    ins[2] = Instruction("COPY", "adapter-lora", "content")
    prov = {"backbone": lambda: bb, "adapter-lora": lambda: ad}
    src.build_image("twin", "v1", ins, prov, parent=("base", "v1"))

    stats = push_delta(src, remote, "twin", "v1")
    assert stats.blobs_sent == 0           # content all held via base
    assert stats.layers_rekey_verified >= 1
    assert stats.layers_deep_verified == 0
    assert remote.verify_image("twin", "v1", deep=True) == []


def test_negotiate_rekeys_against_sibling_image(tmp_path, rng):
    """The HaveSet itself names the cross-image twin: a missing layer
    whose (family, checksum) matches a layer committed under ANOTHER
    image is re-keyed, not re-requested."""
    src = mk(tmp_path)
    bb, ad = build_base(src, rng)
    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")

    ins = list(TENANT_INS)
    ins[2] = Instruction("COPY", "adapter-lora", "content")
    prov = {"backbone": lambda: bb, "adapter-lora": lambda: ad}
    src.build_image("twin", "v1", ins, prov, parent=("base", "v1"))

    m, meta = image_meta(src, "twin")
    have = DeltaReceiver(remote).negotiate("twin", meta)
    base_m, _ = remote.read_image("base", "v1")
    assert set(have.rekey.values()) <= set(base_m.layer_ids)
    assert have.rekey                      # at least the adapter twin


# ----------------------------------------------------------- trust model
def _rekey_consistent(store, name, tag, edit_leaf):
    """In-place mutation under the SAME layer ids, self-consistently
    re-chained — the strongest malicious-pusher forgery."""
    m, cfg = store.read_image(name, tag)
    layers = [store.read_layer(lid, use_cache=False) for lid in m.layer_ids]
    target = next(l for l in layers if not l.empty)
    payload = store.load_layer_payload(target)
    payload[edit_leaf] = payload[edit_leaf].copy()
    payload[edit_leaf][0] = -123.0
    apply_edits(store, target, diff_layer_host(target, payload),
                BuildReport())
    parent, checksums, chains = None, {}, {}
    for layer in layers:
        layer.chain = chain_checksum(parent, layer.checksum,
                                     layer.instruction.text)
        store.write_layer(layer)
        checksums[layer.layer_id] = layer.checksum
        chains[layer.layer_id] = layer.chain
        parent = layer.chain
    new_cfg = ImageConfig(config_id=new_uuid(), arch=cfg.arch,
                          version=cfg.version + 1,
                          layer_checksums=checksums, layer_chains=chains,
                          history=cfg.history)
    return m, new_cfg


def test_mutation_gate_fires_across_image_names(tmp_path, rng):
    """A push of image "tenant" reusing a layer id the remote committed
    under image "base" — with DIVERGED content — is rejected before any
    byte moves. The gate spans the whole namespace, not just the pushed
    image's own tags."""
    src = mk(tmp_path)
    build_base(src, rng)
    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")

    m, new_cfg = _rekey_consistent(src, "base", "v1", "w")
    forged = Manifest(name="tenant", tag="v1", layer_ids=list(m.layer_ids),
                      config_id=new_cfg.config_id)
    src.write_image(forged, new_cfg)
    with pytest.raises(PushRejected):
        push_delta(src, remote, "tenant", "v1")
    assert remote.verify_image("base", "v1", deep=True) == []


def test_orphan_descriptor_not_vouched_by_sibling_commit(tmp_path, rng):
    """A descriptor left behind by a crashed push is NOT "held" just
    because a sibling image is committed: negotiate reports it missing and
    the retry re-receives + re-verifies it."""
    src = mk(tmp_path)
    bb, _ = build_base(src, rng)
    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")

    build_tenant(src, "tenant", bb, adapter_payload(rng, 3.0))
    m, meta = image_meta(src, "tenant")
    adapter_lid = next(lid for lid in m.layer_ids
                       if src.read_layer(lid).instruction.arg == "adapter")
    # simulate the crashed earlier push: descriptor lands, no manifest
    remote.write_layer(src.read_layer(adapter_lid))

    have = DeltaReceiver(remote).negotiate("tenant", meta)
    assert adapter_lid in have.missing_layers
    assert adapter_lid not in have.held_checksums
    stats = push_delta(src, remote, "tenant", "v1")
    assert stats.layers_deep_verified >= 1       # re-verified, not trusted
    assert remote.verify_image("tenant", "v1", deep=True) == []


def test_torn_orphan_blob_dropped_and_resent(tmp_path, rng):
    """An uncommitted blob whose bytes don't match its address (torn
    write from a crash) is re-hashed on probe, dropped and re-sent — a
    sibling image's commit never vouches for bytes it doesn't reach."""
    src = mk(tmp_path)
    bb, _ = build_base(src, rng)
    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")

    build_tenant(src, "tenant", bb, adapter_payload(rng, 3.0))
    adapter_only = sorted(image_chunks(src, "tenant") -
                          image_chunks(src, "base"))
    torn = adapter_only[0]
    path = remote._blob_path(torn)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(b"torn garbage from a crashed push")

    stats = push_delta(src, remote, "tenant", "v1")
    assert stats.blobs_hashed_remote >= 1
    assert remote.read_blob(torn) == src.read_blob(torn)
    assert remote.verify_image("tenant", "v1", deep=True) == []


# -------------------------------------------------------------------- gc
def test_gc_shared_base_blob_survives_tenant_removal(tmp_path, rng):
    """Mark-and-sweep roots span the whole namespace: removing one tenant
    sweeps exactly its exclusive blobs; the backbone survives because the
    base image (and the other tenant) still reach it."""
    store = mk(tmp_path)
    bb, _ = build_base(store, rng)
    build_tenant(store, "tenant1", bb, adapter_payload(rng, 2.0))
    build_tenant(store, "tenant2", bb, adapter_payload(rng, 3.0))

    chunks = {n: image_chunks(store, n)
              for n in ("base", "tenant1", "tenant2")}
    exclusive1 = chunks["tenant1"] - chunks["base"] - chunks["tenant2"]
    assert exclusive1

    assert store.remove_image("tenant1", "v1")
    stats = store.gc()
    assert stats["blobs_swept"] == len(exclusive1)   # exactly, no more
    for h in chunks["base"] | chunks["tenant2"]:
        assert store.has_blob(h)
    assert store.verify_image("base", "v1", deep=True) == []
    assert store.verify_image("tenant2", "v1", deep=True) == []

    # removing the LAST holders sweeps everything
    assert store.remove_image("tenant2", "v1")
    assert store.remove_image("base", "v1")
    store.gc()
    for h in chunks["base"] | chunks["tenant2"]:
        assert not store.has_blob(h)


def test_lease_on_one_image_pins_blobs_shared_with_another(tmp_path, rng):
    """A retention lease on image A's tag keeps its manifest a GC root,
    transitively pinning blobs that image B also reached — even after B
    is removed and collected."""
    store = mk(tmp_path)
    bb, _ = build_base(store, rng)
    build_tenant(store, "tenant", bb, adapter_payload(rng, 2.0))
    shared = image_chunks(store, "base") & image_chunks(store, "tenant")
    assert shared

    store.acquire_lease("base", "v1", owner="edge-0", ttl_s=300.0)
    assert store.remove_image("tenant", "v1")        # tenant not leased
    store.gc()
    for h in shared:
        assert store.has_blob(h)                     # pinned via base

    assert store.remove_image("base", "v1") is False  # lease refuses
    store.release_lease("base", "edge-0")
    assert store.remove_image("base", "v1")
    store.gc()
    assert not any(store.has_blob(h) for h in shared)


def test_release_lease_owner_wide_spans_images(tmp_path, rng):
    """release_lease(None, owner) drops ONE owner's leases across every
    image — the relay's converged-child cleanup — without touching other
    owners' pins."""
    store = mk(tmp_path)
    bb, _ = build_base(store, rng)
    build_tenant(store, "tenant", bb, adapter_payload(rng, 2.0))
    store.acquire_lease("base", "v1", owner="relay/child-0", ttl_s=300.0)
    store.acquire_lease("tenant", "v1", owner="relay/child-0", ttl_s=300.0)
    store.acquire_lease("base", "v1", owner="operator", ttl_s=300.0)

    store.release_lease(None, "relay/child-0")
    assert not store.leased("tenant", "v1")
    assert store.leased("base", "v1")                # operator still pins


def test_relay_leases_pin_every_image_during_fan(tmp_path, rng):
    """While a relay fans a tenant image downstream, EVERY image at the
    relay store is leased — cross-image-vouched blobs can't be pruned out
    from under a lagging child — and the leases are released once the
    children converge."""
    src = mk(tmp_path)
    bb, _ = build_base(src, rng)
    build_tenant(src, "tenant", bb, adapter_payload(rng, 3.0))
    relay_store = mk(tmp_path, "relay")
    push_delta(src, relay_store, "base", "v1")

    rn = RelayNode(relay_store, children=[str(tmp_path / "child")],
                   lease_ttl_s=120.0)
    _, meta = image_meta(src, "tenant")
    rn.begin_push()
    rn.negotiate("tenant", meta)
    # the SIBLING image is pinned for the fan's duration
    assert relay_store.leased("base", "v1")
    assert relay_store.remove_image("base", "v1") is False

    fan = replicate_fanout(src, [rn], "tenant", "v1")
    assert fan.deep_ok
    assert not relay_store.leased("base", "v1")      # released on converge
    child = LayerStore(str(tmp_path / "child"))
    assert child.verify_image("tenant", "v1", deep=True) == []


# ------------------------------------------------------------ fleet paths
def test_fanout_tenant_to_base_holding_replicas(tmp_path, rng):
    """replicate_fanout of a fresh fine-tune to replicas already holding
    the base: one negotiation round, per-replica wire = adapter delta
    only, zero base-blob reads at the source."""
    src = mk(tmp_path)
    bb, _ = build_base(src, rng)
    replicas = [mk(tmp_path, f"r{i}") for i in range(2)]
    for r in replicas:
        push_delta(src, r, "base", "v1")

    build_tenant(src, "tenant", bb, adapter_payload(rng, 3.0))
    adapter_only = image_chunks(src, "tenant") - image_chunks(src, "base")
    reads = instrument_reads(src)
    fan = replicate_fanout(src, replicas, "tenant", "v1")

    assert fan.ok and fan.negotiation_rounds == 1
    assert set(reads) <= adapter_only
    assert fan.source_blob_reads == fan.blobs_broadcast == len(adapter_only)
    for r, res in zip(replicas, fan.replicas):
        assert res.stats.blobs_sent == len(adapter_only)
        assert r.verify_image("tenant", "v1", deep=True) == []


def test_export_delta_base_images_hint_shrinks_bundle(tmp_path, rng):
    """Offline bundles: export_delta(..., base_images=["base"]) diffs the
    tenant against the sibling image too, carrying only adapter layers and
    blobs — and a base-holding receiver imports it cleanly."""
    src = mk(tmp_path)
    bb, _ = build_base(src, rng)
    build_tenant(src, "tenant", bb, adapter_payload(rng, 3.0))
    adapter_only = image_chunks(src, "tenant") - image_chunks(src, "base")

    full = export_delta(src, "tenant", "v1")
    slim = export_delta(src, "tenant", "v1", base_images=["base"])
    assert len(slim) < len(full)

    from repro.core import decode_delta
    bundle = decode_delta(slim)
    assert bundle.base_images == ["base"]
    assert set(bundle.blobs) == adapter_only

    remote = mk(tmp_path, "remote")
    push_delta(src, remote, "base", "v1")
    import_delta(remote, slim)
    assert remote.verify_image("tenant", "v1", deep=True) == []


def test_ckpt_manager_fleet_shared_store(tmp_path, rng):
    """CheckpointManager multi-tenancy end to end: a tenant manager
    sharing the trainer's store forks its first save from the base image
    (base_image=), reusing the base's unchanged layer ids, so replicating
    the tenant to a base-holding replica ships only the adapter delta."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy

    policy = CheckpointPolicy(async_write=False, incremental=True,
                              chunk_bytes=512, every_steps=1)
    base_mgr = CheckpointManager(str(tmp_path / "train"), arch="toy",
                                 policy=policy, image="base-model")
    params = {"embed": {"w": rng.standard_normal(2048).astype(np.float32)},
              "blocks": {"b0": rng.standard_normal(2048).astype(np.float32)},
              "head": {"w": rng.standard_normal(256).astype(np.float32)}}
    opt = {"m": np.zeros(16, np.float32)}
    base_mgr.save(0, params, opt)
    base_tag = base_mgr.tag_of(0)

    tenant_params = {**params,
                     "head": {"w": params["head"]["w"] * 2.0}}
    tenant_mgr = CheckpointManager("", arch="toy", policy=policy,
                                   image="tenant-a",
                                   base_image=("base-model", base_tag),
                                   store=base_mgr.store)
    rep = tenant_mgr.save(0, tenant_params, opt)
    assert rep.layers_cached >= 3          # FROM + embed + blocks reused

    store = base_mgr.store
    adapter_only = image_chunks(store, "tenant-a", base_tag) - \
        image_chunks(store, "base-model", base_tag)
    replica = mk(tmp_path, "replica")
    push_delta(store, replica, "base-model", base_tag)
    reads = instrument_reads(store)
    stats = push_delta(store, replica, "tenant-a", base_tag)
    assert set(reads) <= adapter_only
    assert stats.blobs_sent == len(adapter_only)
    assert replica.verify_image("tenant-a", base_tag, deep=True) == []

    # restore isolation: each tenant reads back its own head
    got, _, _ = tenant_mgr.restore(0)
    np.testing.assert_array_equal(got["head"]["w"], tenant_params["head"]["w"])
    got, _, _ = base_mgr.restore(0)
    np.testing.assert_array_equal(got["head"]["w"], params["head"]["w"])


def test_follower_pull_dedups_against_preseeded_base(tmp_path, rng):
    """A serving follower whose local store was pre-seeded with the base
    image pulls a tenant checkpoint as an adapter-sized delta — the pull
    negotiates against the local store's whole committed namespace."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower

    policy = CheckpointPolicy(async_write=False, incremental=True,
                              chunk_bytes=512, every_steps=1)
    base_mgr = CheckpointManager(str(tmp_path / "train"), arch="toy",
                                 policy=policy, image="base-model")
    params = {"embed": {"w": rng.standard_normal(2048).astype(np.float32)},
              "blocks": {"b0": rng.standard_normal(2048).astype(np.float32)},
              "head": {"w": rng.standard_normal(256).astype(np.float32)}}
    opt = {"m": np.zeros(16, np.float32)}
    base_mgr.save(0, params, opt)
    base_tag = base_mgr.tag_of(0)

    tenant_mgr = CheckpointManager("", arch="toy", policy=policy,
                                   image="tenant-a",
                                   base_image=("base-model", base_tag),
                                   store=base_mgr.store)
    tenant_mgr.save(0, {**params, "head": {"w": params["head"]["w"] * 2.0}},
                    opt)

    local = mk(tmp_path, "serve-local")
    push_delta(base_mgr.store, local, "base-model", base_tag)
    base_blobs = image_chunks(local, "base-model", base_tag)

    follower = CheckpointFollower(base_mgr.store, local, image="tenant-a",
                                  sparse=False)
    assert follower.poll() is not None
    assert follower.last_step == 0
    assert follower.last_pull.blobs_sent < len(base_blobs)
    adapter_only = image_chunks(base_mgr.store, "tenant-a", base_tag) - \
        image_chunks(base_mgr.store, "base-model", base_tag)
    assert follower.last_pull.blobs_sent == len(adapter_only)
    assert local.verify_image("tenant-a", base_tag, deep=True) == []


# ------------------------------------------------------- holdings caching
def test_holdings_index_invalidation(tmp_path, rng):
    """The cached holdings index must never serve stale answers across
    write_image/remove_image — a fresh tenant commit is immediately
    visible to the next negotiation."""
    store = mk(tmp_path)
    bb, _ = build_base(store, rng)
    idx = store.holdings_index()
    assert idx.images == ["base"]

    build_tenant(store, "tenant", bb, adapter_payload(rng, 2.0))
    idx2 = store.holdings_index()
    assert idx2.images == ["base", "tenant"]
    m, _ = store.read_image("tenant", "v1")
    assert set(m.layer_ids) <= idx2.committed_layers

    store.remove_image("tenant", "v1")
    assert store.holdings_index().images == ["base"]
    # fresh=True bypasses the cache entirely
    assert store.holdings_index(fresh=True).images == ["base"]
