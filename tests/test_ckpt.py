"""CheckpointManager: full vs incremental saves, restore, async, GC."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.ckpt.manager import flatten_tree, unflatten_tree


def tiny_state(key, scale=1.0):
    ks = jax.random.split(key, 3)
    params = {"embed": jax.random.normal(ks[0], (64, 8), jnp.float32),
              "blocks": {"w": jax.random.normal(ks[1], (4, 8, 8))},
              "final_norm": jnp.ones((8,))}
    opt = {"step": jnp.int32(0),
           "m": jax.tree.map(lambda a: jnp.zeros_like(a), params)}
    return params, opt


def test_flatten_roundtrip():
    params, opt = tiny_state(jax.random.PRNGKey(0))
    flat = flatten_tree(params)
    back = unflatten_tree(flat)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        params, back))


def policy(**kw):
    defaults = dict(every_steps=1, keep=3, incremental=True,
                    async_write=False, chunk_bytes=256)
    defaults.update(kw)
    return CheckpointPolicy(**defaults)


def test_save_restore_roundtrip(tmp_path):
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy())
    mgr.save(10, params, opt)
    out = mgr.restore()
    assert out is not None
    p2, o2, step = out
    assert step == 10
    assert np.array_equal(np.asarray(p2["embed"]), np.asarray(params["embed"]))
    assert np.array_equal(np.asarray(o2["m"]["blocks"]["w"]),
                          np.asarray(opt["m"]["blocks"]["w"]))


def test_incremental_save_is_o_delta(tmp_path):
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy())
    r1 = mgr.save(0, params, opt)
    # change one small slice of one tensor
    params2 = jax.tree.map(lambda a: a, params)
    params2["blocks"] = {"w": params["blocks"]["w"].at[0, 0, 0].add(1.0)}
    r2 = mgr.save(1, params2, opt)
    # blocks layer + opt layer (its embedded step counter changed)
    assert 1 <= r2.layers_injected <= 2
    assert r2.bytes_serialized < r1.bytes_serialized / 5
    p3, _, step = mgr.restore()
    assert step == 1
    assert np.array_equal(np.asarray(p3["blocks"]["w"]),
                          np.asarray(params2["blocks"]["w"]))


def test_unchanged_save_writes_almost_nothing(tmp_path):
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy())
    mgr.save(0, params, opt)
    r = mgr.save(1, params, opt)
    # only the embedded step-counter chunk changes
    assert r.chunks_written <= 1
    assert r.bytes_serialized <= 256
    assert mgr.latest_step() == 1        # still committed as a new tag


def test_async_save_and_wait(tmp_path):
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy(async_write=True))
    mgr.save(0, params, opt)
    rep = mgr.wait()
    assert rep is not None
    assert mgr.latest_step() == 0


def test_gc_keeps_k(tmp_path):
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy(keep=2))
    for s in range(5):
        mgr.save(s, params, opt)
    tags = [t for t in mgr.store.list_tags("ckpt") if t.startswith("step-")]
    assert len(tags) == 2
    assert mgr.latest_step() == 4


def test_structure_change_falls_back_to_full(tmp_path):
    """'Compiled' case: tree structure changes -> rebuild, not inject."""
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy())
    mgr.save(0, params, opt)
    params2 = dict(params)
    params2["extra"] = jnp.ones((16,))    # new leaf = structure change
    mgr.save(1, params2, opt)
    p3, _, _ = mgr.restore()
    assert "extra" in p3


def test_fingerprint_mode_equivalent(tmp_path):
    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny",
                            policy(use_fingerprints=True))
    mgr.save(0, params, opt)
    params2 = jax.tree.map(lambda a: a, params)
    params2["embed"] = params["embed"].at[5, 2].add(3.0)
    mgr.save(1, params2, opt)
    p3, _, _ = mgr.restore()
    assert np.array_equal(np.asarray(p3["embed"]),
                          np.asarray(params2["embed"]))


def test_mixed_tags_skipped_by_step_parsing(tmp_path):
    """Regression: user-pushed tags (``best``, ``release``, ``step-final``,
    a non-canonical ``step-9``) in the checkpoint image must be skipped by
    step parsing — never crash ``latest_step``, never be mistaken for the
    newest checkpoint, and never be deleted by retention."""
    import dataclasses

    from repro.ckpt.manager import latest_step, prune_steps, step_of_tag

    assert step_of_tag("step-00000042") == 42
    assert step_of_tag("step-123456789") == 123456789   # >8 digits grows
    for bad in ("best", "release", "step-final", "step-9",
                "step-000000009", "step--1", "step-"):
        assert step_of_tag(bad) is None

    params, opt = tiny_state(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), "tiny", policy(keep=2))
    for s in range(3):
        mgr.save(s, params, opt)
    # pin user tags onto the image (e.g. a promoted "best" checkpoint)
    m, c = mgr.store.read_image("ckpt", "step-00000002")
    for tag in ("best", "step-final", "step-9"):
        mgr.store.write_image(dataclasses.replace(m, tag=tag), c)

    # parsing skips them ('step-9' would sort lexicographically AFTER
    # 'step-00000002' — it must not shadow the real newest step)
    assert latest_step(mgr.store, "ckpt", fresh=True) == 2
    assert mgr.latest_step() == 2

    # retention prunes only canonical step tags, keeps every pin
    prune_steps(mgr.store, "ckpt", 1)
    tags = set(mgr.store.list_tags("ckpt"))
    assert tags == {"best", "step-final", "step-9", "step-00000002"}

    # the save path keeps working with mixed tags present (it derives the
    # parent revision via latest_step internally)
    mgr.save(3, params, opt)
    assert mgr.latest_step() == 3
    p, _, s = mgr.restore()
    assert s == 3
