"""SSD (Mamba-2) math: chunked vs sequential, conv, decode continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (causal_conv, causal_conv_step, ssd_chunked,
                              ssd_decode_step, ssd_reference)


def rand_inputs(key, B, S, H, P, G, N):
    ks = jax.random.split(key, 6)
    return (jax.random.normal(ks[0], (B, S, H, P)),
            jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))),
            -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5),
            jax.random.normal(ks[3], (B, S, G, N)) * 0.3,
            jax.random.normal(ks[4], (B, S, G, N)) * 0.3,
            jax.random.normal(ks[5], (H,)) * 0.1)


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 64, 3, 8, 1, 16, 8), (2, 64, 3, 8, 1, 16, 64),
    (1, 96, 4, 16, 2, 8, 32), (2, 33, 5, 4, 1, 8, 16),   # ragged S
])
def test_chunked_matches_sequential(B, S, H, P, G, N, chunk):
    x, dt, A, Bc, Cc, D = rand_inputs(jax.random.PRNGKey(0), B, S, H, P, G, N)
    y_ref, h_ref = ssd_reference(x, dt, A, Bc, Cc, D)
    y, h = ssd_chunked(x, dt, A, Bc, Cc, D, chunk=chunk)
    assert np.abs(np.asarray(y - y_ref)).max() < 2e-5
    assert np.abs(np.asarray(h - h_ref)).max() < 2e-5


def test_decode_continues_prefill_state():
    B, S, H, P, G, N = 2, 48, 3, 8, 1, 16
    x, dt, A, Bc, Cc, D = rand_inputs(jax.random.PRNGKey(1), B, S, H, P, G, N)
    y_full, h_full = ssd_reference(x, dt, A, Bc, Cc, D)
    # prefill on first S-4, then 4 decode steps
    Sp = S - 4
    _, h = ssd_chunked(x[:, :Sp], dt[:, :Sp], A, Bc[:, :Sp], Cc[:, :Sp], D,
                       chunk=16)
    ys = []
    for t in range(Sp, S):
        h, y_t = ssd_decode_step(h, x[:, t], dt[:, t], A, Bc[:, t],
                                 Cc[:, t], D)
        ys.append(y_t)
    y_dec = jnp.stack(ys, axis=1)
    assert np.abs(np.asarray(y_dec - y_full[:, Sp:])).max() < 2e-5
    assert np.abs(np.asarray(h - h_full)).max() < 2e-5


def test_conv_train_vs_step():
    B, S, H, P, K = 2, 40, 3, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (B, S, H, P))
    w = jax.random.normal(ks[1], (H, P, K)) * 0.3
    b = jax.random.normal(ks[2], (H, P)) * 0.1
    y = causal_conv(x, w, b)
    st = jnp.zeros((B, K - 1, H, P))
    outs = []
    for t in range(S):
        st, yt = causal_conv_step(st, x[:, t], w, b)
        outs.append(yt)
    assert np.abs(np.asarray(jnp.stack(outs, 1) - y)).max() < 1e-5
