"""Multi-hop relay replication (trainer -> relay -> edge tiers): the same
negotiated plan re-fanned tier by tier, with exactly one parent read and at
most one local read per blob regardless of fan-out width, in-flight
streaming gated so a child never commits before its relay, per-child
failure isolation with converging retries, SIGKILL atomicity one tier
deeper than the fan-out tests, and the offline (bundle) relay form."""
import collections
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import (Instruction, LayerStore, PushRejected, RelayNode,
                        export_delta, import_delta, inject_payload_update,
                        push_delta, replicate_fanout)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "src", "content"),
    Instruction("RUN", "deps", "content"),
    Instruction("CMD", "run", "config"),
]


def mk(tmp_path, name):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


def make_payloads(rng):
    return {
        "src": {"a.py": rng.standard_normal(1000).astype(np.float32),
                "b.py": rng.standard_normal(500).astype(np.float32)},
        "deps": {"lib": rng.standard_normal(4000).astype(np.float32)},
    }


def build_v1(store, payloads):
    prov = {k: (lambda v=v: v) for k, v in payloads.items()}
    store.build_image("app", "v1", INS, prov)


def inject_v2(store, payloads):
    src2 = {k: v.copy() for k, v in payloads["src"].items()}
    src2["b.py"][3] = 42.0                        # ONE changed 512 B chunk
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"deps": lambda: payloads["deps"]})
    return src2


def snapshot(store, name, tag):
    manifest, config = store.read_image(name, tag)
    layers, blobs = {}, {}
    for lid in manifest.layer_ids:
        with open(store._layer_path(lid), "rb") as f:
            layers[lid] = f.read()
        for rec in store.read_layer(lid).records:
            for h in rec.chunks:
                blobs[h] = store.read_blob(h)
    return {"manifest": manifest.to_json(), "config": config.to_json(),
            "layers": layers, "blobs": blobs}


def count_reads(store):
    """Shadow ``read_blob`` with a counting wrapper (independent proof of
    the one-read-per-tier claims)."""
    reads = []
    orig = store.read_blob
    store.read_blob = lambda h: (reads.append(h), orig(h))[1]
    return reads


# ----------------------------------------------------------------- topology
def test_relay_bit_identical_to_push_delta(tmp_path, rng):
    """trainer -> relay -> 2 edges: every tier ends bit-identical to a
    direct push_delta of the same tag, for both the full image and the
    one-chunk delta."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0"), mk(tmp_path, "e1")])
    single = mk(tmp_path, "single")
    for tag in ("v1", "v2"):
        fan = replicate_fanout(store, [relay], "app", tag)
        assert fan.ok and fan.deep_ok
        assert fan.replicas[0].children is relay.fan
        push_delta(store, single, "app", tag)
        want = snapshot(single, "app", tag)
        for s in relay.all_stores():
            assert snapshot(s, "app", tag) == want
            assert s.verify_image("app", tag, deep=True) == []


def test_relay_inflight_one_parent_read_zero_local_reads(tmp_path, rng):
    """Warm topology + one changed chunk: the relay reads the blob from
    its parent exactly once, forwards it to both children straight from
    the wire buffer (ZERO local reads), and every tier still pays exactly
    one negotiation round. Per-hop wire stays O(changed bytes)."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0"), mk(tmp_path, "e1")],
                      source="inflight")
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok
    inject_v2(store, payloads)

    parent_reads = count_reads(store)
    local_reads = count_reads(relay.store)
    fan = replicate_fanout(store, [relay], "app", "v2")
    del store.read_blob, relay.store.read_blob
    assert fan.deep_ok
    assert fan.negotiation_rounds == 1
    assert relay.fan.negotiation_rounds == 1
    assert len(parent_reads) == fan.source_blob_reads == 1
    assert local_reads == [] and relay.local_blob_reads == 0
    assert relay.inflight_blobs == 1
    # per-hop wire: each hop carried the one changed chunk (+ metadata)
    assert fan.replicas[0].stats.bytes_payload == 512
    for rep in relay.fan.replicas:
        assert rep.stats.bytes_payload == 512
        assert rep.stats.bytes_sent == \
            rep.stats.bytes_payload + rep.stats.bytes_meta


def test_relay_commit_mode_defers_fan_single_local_read(tmp_path, rng):
    """source="commit": nothing is forwarded until the relay committed;
    the owed blob is then read from the relay's store exactly once and
    broadcast to both children."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0"), mk(tmp_path, "e1")],
                      source="commit")
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok
    inject_v2(store, payloads)

    events = []
    for label, s in (("relay", relay.store),
                     ("e0", relay.children[0].store),
                     ("e1", relay.children[1].store)):
        orig = s.write_image

        def hook(manifest, config, _orig=orig, _label=label):
            events.append(f"commit:{_label}")
            return _orig(manifest, config)
        s.write_image = hook
    for i in (0, 1):
        s = relay.children[i].store
        orig_wb = s.write_blob

        def hook_b(h, data, _orig=orig_wb, _i=i):
            events.append(f"blob:e{_i}")
            return _orig(h, data)
        s.write_blob = hook_b
    try:
        fan = replicate_fanout(store, [relay], "app", "v2")
    finally:
        for s in [relay.store] + [c.store for c in relay.children]:
            s.__dict__.pop("write_image", None)
            s.__dict__.pop("write_blob", None)
    assert fan.deep_ok
    assert relay.inflight_blobs == 0
    assert relay.local_blob_reads == 1          # once, not once per child
    # the relay committed BEFORE any child saw a byte, and both children
    # committed after receiving
    assert events.index("commit:relay") < events.index("blob:e0")
    assert events.index("blob:e0") < events.index("commit:e0")
    assert events.index("blob:e1") < events.index("commit:e1")


def test_relay_inflight_child_commit_gated_on_relay_commit(tmp_path, rng):
    """In-flight mode streams bytes to children BEFORE the relay commits,
    but a child commit still only happens after the relay's."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0")], source="inflight")
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok
    inject_v2(store, payloads)

    events = []
    child_store = relay.children[0].store
    orig_ci = child_store.write_image
    orig_cb = child_store.write_blob
    orig_ri = relay.store.write_image
    child_store.write_image = lambda m, c: (events.append("child_commit"),
                                            orig_ci(m, c))[1]
    child_store.write_blob = lambda h, d: (events.append("child_blob"),
                                           orig_cb(h, d))[1]
    relay.store.write_image = lambda m, c: (events.append("relay_commit"),
                                            orig_ri(m, c))[1]
    try:
        fan = replicate_fanout(store, [relay], "app", "v2")
    finally:
        for s in (child_store, relay.store):
            s.__dict__.pop("write_image", None)
            s.__dict__.pop("write_blob", None)
    assert fan.deep_ok
    # streamed in flight: the child had the byte before the relay's commit
    assert events.index("child_blob") < events.index("relay_commit")
    assert events.index("relay_commit") < events.index("child_commit")


def test_relay_stale_children_one_local_read_per_blob(tmp_path, rng):
    """Children lagging behind an up-to-date relay: every blob the child
    tier lacks is read from the relay's store exactly ONCE and broadcast
    to all three children — never re-read or re-hashed per child."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    hot = mk(tmp_path, "hot")
    push_delta(store, hot, "app", "v2")           # relay already current
    relay = RelayNode(hot, children=[mk(tmp_path, f"s{i}")
                                     for i in range(3)])
    local_reads = count_reads(hot)
    fan = replicate_fanout(store, [relay], "app", "v2")
    del hot.read_blob
    assert fan.deep_ok
    assert fan.replicas[0].stats.bytes_payload == 0     # parent sent nothing
    counts = collections.Counter(local_reads)
    assert relay.local_blob_reads == len(counts)
    assert counts and max(counts.values()) == 1         # once per blob
    for child in relay.children:
        assert child.store.verify_image("app", "v2", deep=True) == []


def test_relay_mixed_staleness_children(tmp_path, rng):
    """Children at different states behind one relay: one warm (delta
    only), one cold (everything), one current (nothing) — each child's
    wire is O(what THAT child lacked), carved from one relay plan."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    warm, cold, done = (mk(tmp_path, n) for n in ("warm", "cold", "done"))
    push_delta(store, warm, "app", "v1")
    push_delta(store, done, "app", "v2")
    relay = RelayNode(mk(tmp_path, "relay"), children=[warm, cold, done])
    fan = replicate_fanout(store, [relay], "app", "v2")
    assert fan.deep_ok
    s_warm, s_cold, s_done = (r.stats for r in relay.fan.replicas)
    assert s_warm.blobs_sent == 1 and s_warm.bytes_payload == 512
    assert s_cold.blobs_sent > 1
    assert s_done.blobs_sent == 0 and s_done.layers_dedup > 0
    assert s_warm.bytes_sent < s_cold.bytes_sent / 2
    for child in relay.children:
        assert child.store.verify_image("app", "v2", deep=True) == []


def test_nested_relay_three_tiers(tmp_path, rng):
    """trainer -> relay -> sub-relay -> edge: tiers nest; every store ends
    deep-verified and the edge payload is bit-identical to the trainer."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    src2 = inject_v2(store, payloads)
    sub = RelayNode(mk(tmp_path, "sub"), children=[mk(tmp_path, "edge")])
    relay = RelayNode(mk(tmp_path, "relay"), children=[sub])
    fan = replicate_fanout(store, [relay], "app", "v2", source="inflight")
    assert fan.deep_ok
    assert fan.replicas[0].children.replicas[0].children is sub.fan
    edge = mk(tmp_path, "edge")
    assert edge.verify_image("app", "v2", deep=True) == []
    flat = edge.load_image_payload("app", "v2")
    assert np.array_equal(flat["b.py"], src2["b.py"])
    assert np.array_equal(flat["lib"], payloads["deps"]["lib"])


# ------------------------------------------------------- failure isolation
def test_relay_child_failure_isolated_and_retry_converges(tmp_path, rng):
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0"), mk(tmp_path, "e1")])
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok
    inject_v2(store, payloads)

    class Boom(RuntimeError):
        pass

    def dying(layer, encoded=None):
        raise Boom("edge disk full")

    relay.children[0].store.write_layer = dying
    try:
        fan = replicate_fanout(store, [relay], "app", "v2")
    finally:
        del relay.children[0].store.write_layer
    # the relay itself committed; only the sick child is isolated
    assert fan.ok and not fan.deep_ok
    assert relay.store.verify_image("app", "v2", deep=True) == []
    assert relay.fan.replicas[0].error is not None
    assert isinstance(relay.fan.replicas[0].exception, Boom)
    assert relay.fan.replicas[0].stats is None
    assert relay.fan.replicas[1].ok
    assert relay.children[1].store.verify_image("app", "v2", deep=True) == []
    # the failed child kept its previous tag fully intact
    assert relay.children[0].store.list_tags("app") == ["v1"]
    assert relay.children[0].store.verify_image("app", "v1", deep=True) == []

    # retry converges the whole topology; healthy tiers resend nothing
    fan = replicate_fanout(store, [relay], "app", "v2")
    assert fan.deep_ok
    assert fan.replicas[0].stats.bytes_payload == 0
    assert relay.fan.replicas[1].stats.bytes_payload == 0
    assert relay.children[0].store.verify_image("app", "v2", deep=True) == []


def test_relay_failure_means_no_child_commits(tmp_path, rng):
    """A relay whose own commit fails must leave EVERY child at its
    previous tag even though in-flight bytes already reached them — the
    child commit is gated on the relay commit."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0"), mk(tmp_path, "e1")],
                      source="inflight")
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok
    inject_v2(store, payloads)

    class Boom(RuntimeError):
        pass

    def dying_write_image(manifest, config):
        raise Boom("relay commit lost")

    relay.store.write_image = dying_write_image
    try:
        fan = replicate_fanout(store, [relay], "app", "v2")
    finally:
        del relay.store.write_image
    assert not fan.ok
    assert isinstance(fan.replicas[0].exception, Boom)
    # in-flight bytes may have landed as orphans, but no tier committed
    for s in relay.all_stores():
        assert s.list_tags("app") == ["v1"]
        assert s.verify_image("app", "v1", deep=True) == []
    # retry converges every tier (orphans re-verified, never trusted)
    fan = replicate_fanout(store, [relay], "app", "v2")
    assert fan.deep_ok
    for s in relay.all_stores():
        assert s.verify_image("app", "v2", deep=True) == []


def test_relay_child_mutation_gate(tmp_path, rng):
    """A child holding a diverged checksum for a layer id is rejected at
    the child tier's negotiation gate, before any byte reaches it, while
    its sibling and the relay proceed."""
    import dataclasses
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    bad, good = mk(tmp_path, "bad"), mk(tmp_path, "good")
    push_delta(store, bad, "app", "v1")
    m, _ = bad.read_image("app", "v1")
    layer = bad.read_layer(m.layer_ids[1], use_cache=False)
    bad.write_layer(dataclasses.replace(layer, checksum="deadbeef" * 8))
    bad._layer_cache.clear()
    before = bad.read_layer(m.layer_ids[1], use_cache=False).checksum

    # re-fan the SAME tag: the bad child now holds one of its layer ids
    # with a diverged checksum — the paper's in-place mutation signature
    relay = RelayNode(mk(tmp_path, "relay"), children=[bad, good])
    fan = replicate_fanout(store, [relay], "app", "v1")
    assert fan.ok and not fan.deep_ok
    assert isinstance(relay.fan.replicas[0].exception, PushRejected)
    assert relay.fan.replicas[0].stats is None
    assert relay.fan.replicas[1].ok
    assert good.verify_image("app", "v1", deep=True) == []
    # no byte reached the rejected child (its tampered state is untouched)
    assert bad.read_layer(m.layer_ids[1],
                          use_cache=False).checksum == before


def test_source_override_is_per_push_and_reaches_nested_tiers(tmp_path,
                                                              rng):
    """``replicate_fanout(source=...)`` must re-mode the WHOLE subtree for
    that push only: a nested relay obeys the override, and the node's
    configured mode comes back for the next source=None push."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    sub = RelayNode(mk(tmp_path, "sub"), children=[mk(tmp_path, "edge")],
                    source="inflight")
    relay = RelayNode(mk(tmp_path, "relay"), children=[sub],
                      source="inflight")
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok
    inject_v2(store, payloads)

    # override to commit-gated ordering: NO tier may stream pre-commit
    fan = replicate_fanout(store, [relay], "app", "v2", source="commit")
    assert fan.deep_ok
    assert relay.inflight_blobs == 0 and relay.local_blob_reads == 1
    assert sub.inflight_blobs == 0 and sub.local_blob_reads == 1
    # the configured mode survives the override
    assert relay.source == "inflight" and sub.source == "inflight"

    # next push without an override streams in-flight again (both tiers)
    src3 = {k: v.copy() for k, v in payloads["src"].items()}
    src3["a.py"][1] = -3.0
    inject_payload_update(store, "app", "v2", "v3", {"src": src3},
                          providers={"deps": lambda: payloads["deps"]})
    fan = replicate_fanout(store, [relay], "app", "v3")
    assert fan.deep_ok
    assert relay.inflight_blobs == 1 and relay.local_blob_reads == 0
    assert sub.inflight_blobs == 1 and sub.local_blob_reads == 0


def test_unreadable_local_blob_fails_only_its_takers(tmp_path, rng):
    """A serve-local blob the relay can no longer read (retention race,
    bad sector) must fail ONLY the children that needed it — the relay's
    own already-landed commit stays good, and healing the store converges
    the children on retry."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2(store, payloads)
    hot = mk(tmp_path, "hot")
    push_delta(store, hot, "app", "v2")
    relay = RelayNode(hot, children=[mk(tmp_path, "c0"), mk(tmp_path, "c1")])
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok

    # the one blob the children lack for v2 disappears from the relay
    m2, _ = store.read_image("app", "v2")
    m1, _ = store.read_image("app", "v1")
    old = {h for lid in m1.layer_ids
           for rec in store.read_layer(lid).records for h in rec.chunks}
    (owed,) = {h for lid in m2.layer_ids
               for rec in store.read_layer(lid).records
               for h in rec.chunks} - old
    os.remove(hot._blob_path(owed))

    fan = replicate_fanout(store, [relay], "app", "v2")
    assert fan.ok                    # the relay tier itself is healthy
    assert not fan.deep_ok
    assert hot.verify_image("app", "v2", deep=False) == []
    for i in (0, 1):                 # both children needed the lost blob
        assert not relay.fan.replicas[i].ok
        assert relay.children[i].store.list_tags("app") == ["v1"]

    # heal the relay store; the retry converges every child
    hot.write_blob(owed, store.read_blob(owed))
    fan = replicate_fanout(store, [relay], "app", "v2")
    assert fan.deep_ok
    for child in relay.children:
        assert child.store.verify_image("app", "v2", deep=True) == []


# -------------------------------------------------------------- SIGKILL
def _run_kill9(tmp_path, script_body):
    root = str(tmp_path)
    script = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.core import (Instruction, LayerStore, RelayNode,
                                inject_payload_update, replicate_fanout)

        ins = [Instruction("FROM", "base", "config"),
               Instruction("COPY", "src", "content"),
               Instruction("CMD", "run", "config")]
        payloads = {{"src": {{"w": np.arange(2000, dtype=np.float32)}}}}
        root = {root!r}
        store = LayerStore(os.path.join(root, "src"), chunk_bytes=256)
        prov = {{k: (lambda v=v: v) for k, v in payloads.items()}}
        store.build_image("app", "v1", ins, prov)
        relay = RelayNode(LayerStore(os.path.join(root, "relay"),
                                     chunk_bytes=256),
                          children=[LayerStore(os.path.join(root, f"e{{i}}"),
                                               chunk_bytes=256)
                                    for i in range(2)])
        assert replicate_fanout(store, [relay], "app", "v1").deep_ok
        new = {{"src": {{"w": payloads["src"]["w"] + 1.0}}}}
        inject_payload_update(store, "app", "v1", "v2", new)
        print("READY", flush=True)
    """) + textwrap.dedent(script_body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr)
    assert "READY" in r.stdout
    assert "UNREACHABLE" not in r.stdout
    return root


def _assert_tiers_consistent_and_retry(tmp_path):
    """Every tier is fully at v1 or fully at v2 — never torn — and a
    fleet-wide retry converges the whole topology."""
    root = str(tmp_path)
    store = LayerStore(os.path.join(root, "src"), chunk_bytes=256)
    relay = RelayNode(LayerStore(os.path.join(root, "relay"),
                                 chunk_bytes=256),
                      children=[LayerStore(os.path.join(root, f"e{i}"),
                                           chunk_bytes=256)
                                for i in range(2)])
    for s in relay.all_stores():
        tags = s.list_tags("app")
        assert "v1" in tags and set(tags) <= {"v1", "v2"}
        for tag in tags:
            assert s.verify_image("app", tag, deep=True) == []
    fan = replicate_fanout(store, [relay], "app", "v2")
    assert fan.deep_ok
    for s in relay.all_stores():
        assert s.verify_image("app", "v2", deep=True) == []


def test_relay_kill9_mid_pull_leaves_no_torn_tier(tmp_path):
    """SIGKILL inside the relay's own commit (blobs already landed at the
    relay AND streamed in-flight to the children): no tier may commit, no
    tier may tear, retry converges."""
    _run_kill9(tmp_path, """
        def dying_write_image(manifest, config):
            os.kill(os.getpid(), signal.SIGKILL)
        relay.store.write_image = dying_write_image
        replicate_fanout(store, [relay], "app", "v2", source="inflight")
        print("UNREACHABLE", flush=True)
    """)
    _assert_tiers_consistent_and_retry(tmp_path)


def test_relay_kill9_mid_refan_leaves_no_torn_tier(tmp_path):
    """SIGKILL one tier deeper — inside a child's commit, after the relay
    committed: the relay is at v2, the dying child must stay fully at v1,
    and the fleet retry converges everyone."""
    _run_kill9(tmp_path, """
        def dying_write_image(manifest, config):
            os.kill(os.getpid(), signal.SIGKILL)
        relay.children[1].store.write_image = dying_write_image
        replicate_fanout(store, [relay], "app", "v2", source="inflight")
        print("UNREACHABLE", flush=True)
    """)
    # the relay committed before the child died
    root = str(tmp_path)
    relay_store = LayerStore(os.path.join(root, "relay"), chunk_bytes=256)
    assert set(relay_store.list_tags("app")) == {"v1", "v2"}
    _assert_tiers_consistent_and_retry(tmp_path)


# ---------------------------------------------------------- integrations
def test_manager_replicate_relay_topology(tmp_path, rng):
    """CheckpointManager.replicate(relay=...): plain remotes and relay
    tiers ride one fan-out; every edge ends bit-identical to the save."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fan = mgr.replicate(
        remote=[str(tmp_path / "plain")],
        relay={str(tmp_path / "r0"): [str(tmp_path / "e0"),
                                      str(tmp_path / "e1")]},
        source="inflight")
    assert fan.deep_ok and len(fan.replicas) == 2
    assert fan.replicas[0].children is None          # the plain remote
    assert fan.replicas[1].children is not None
    for name in ("plain", "r0", "e0", "e1"):
        s = LayerStore(str(tmp_path / name))
        assert s.verify_image("ckpt", "step-00000000", deep=True) == []
        flat = s.load_image_payload("ckpt", "step-00000000")
        assert np.array_equal(flat["params/w"], params["w"])

    # nested dict children build intermediate tiers, not junk leaf stores
    fan = mgr.replicate(relay={str(tmp_path / "n0"):
                               [{str(tmp_path / "n1"):
                                 [str(tmp_path / "n_edge")]}]})
    assert fan.deep_ok
    for name in ("n0", "n1", "n_edge"):
        assert LayerStore(str(tmp_path / name)).verify_image(
            "ckpt", "step-00000000", deep=True) == []

    # argument validation: a destination is required, and source= without
    # any relay in reach is a caller error, not a silent no-op
    try:
        mgr.replicate()
        raise AssertionError("no-destination replicate must raise")
    except ValueError:
        pass
    try:
        mgr.replicate(remote=str(tmp_path / "plain"), source="commit")
        raise AssertionError("source= on a plain remote must raise")
    except ValueError:
        pass


def test_follower_children_refan(tmp_path, rng):
    """CheckpointFollower(children=...): each poll pulls once from the
    trainer and re-fans to the edge stores; edge payloads stay
    bit-identical to the trainer across sparse polls."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    params = {"w": rng.standard_normal(600).astype(np.float32),
              "b": rng.standard_normal(300).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"),
                             children=[str(tmp_path / "e0"),
                                       str(tmp_path / "e1")])
    upd = fol.poll()
    assert upd is not None and upd.full
    assert fol.last_fan is not None and fol.last_fan.ok

    params2 = dict(params)
    params2["w"] = params["w"].copy()
    params2["w"][5] += 1.0
    mgr.save(1, params2, opt)
    upd = fol.poll()
    assert upd.changed_params == {"w"}
    assert fol.last_fan.ok
    for name in ("e0", "e1"):
        s = LayerStore(str(tmp_path / name))
        assert s.verify_image("ckpt", "step-00000001", deep=True) == []
        flat = s.load_image_payload("ckpt", "step-00000001")
        assert np.array_equal(flat["params/w"], params2["w"])
        assert np.array_equal(flat["params/b"], params["b"])


def test_import_delta_serves_stale_child_from_relay_holdings(tmp_path,
                                                             rng):
    """Offline relay with a child STALER than the bundle's base: chunks
    the bundle doesn't carry (they changed in an earlier hop) but the
    relay holds committed must be served locally — the first import must
    converge the child, not fail its commit."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    src2 = inject_v2(store, payloads)                 # v1 -> v2
    src3 = {k: v.copy() for k, v in src2.items()}
    src3["b.py"][7] = -7.0                            # v2 -> v3
    inject_payload_update(store, "app", "v2", "v3", {"src": src3},
                          providers={"deps": lambda: payloads["deps"]})

    child = mk(tmp_path, "child")
    push_delta(store, child, "app", "v1")             # child at v1 (stale)
    relay_store = mk(tmp_path, "relay")
    push_delta(store, relay_store, "app", "v2")       # relay at v2
    relay = RelayNode(relay_store, children=[child])

    # bundle carries ONLY the v2->v3 delta; the child also lacks v1->v2
    bundle = export_delta(store, "app", "v3", base_tag="v2")
    import_delta(relay, bundle)
    assert relay.fan.ok, [r.error for r in relay.fan.replicas]
    assert child.verify_image("app", "v3", deep=True) == []
    assert np.array_equal(child.load_image_payload("app", "v3")["b.py"],
                          src3["b.py"])


def test_follower_relay_prunes_edge_tier(tmp_path, rng):
    """Edge stores share the follower's retention: polling many steps must
    not grow the edge tier beyond ``keep`` checkpoints."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    params = {"w": rng.standard_normal(600).astype(np.float32)}
    opt = {"m": np.zeros(8, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"), keep=2,
                             children=[str(tmp_path / "e0")])
    for s in range(5):
        params = dict(params, w=params["w"] + 1.0)
        mgr.save(s, params, opt)
        assert fol.poll() is not None
    edge = LayerStore(str(tmp_path / "e0"))
    tags = edge.list_tags("ckpt")
    assert tags == ["step-00000003", "step-00000004"]
    for tag in tags:
        assert edge.verify_image("ckpt", tag, deep=True) == []


def test_negotiations_counter_measures_extra_rounds(tmp_path, rng):
    """``negotiations`` must count across a whole push (reset only at
    ``begin_push``), so FanoutStats.negotiation_rounds can actually
    detect a second round instead of tautologically reading 1."""
    from repro.core import DeltaReceiver
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    m, _ = store.read_image("app", "v1")
    meta = {lid: (store.read_layer(lid).family,
                  store.read_layer(lid).checksum) for lid in m.layer_ids}
    recv = DeltaReceiver(mk(tmp_path, "dst"))
    recv.begin_push()
    recv.negotiate("app", meta)
    recv.negotiate("app", meta)               # a hypothetical second round
    assert recv.negotiations == 2             # measured, not erased
    recv.begin_push()
    assert recv.negotiations == 0


def test_import_delta_refans_offline_bundle(tmp_path, rng):
    """The offline relay: one exported bundle applied at a RelayNode lands
    on the relay AND its children through the same negotiated machinery,
    with the bundle header seeding the child plans."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    src2 = inject_v2(store, payloads)
    relay = RelayNode(mk(tmp_path, "relay"),
                      children=[mk(tmp_path, "e0"), mk(tmp_path, "e1")])
    assert replicate_fanout(store, [relay], "app", "v1").deep_ok

    bundle = export_delta(store, "app", "v2", base_tag="v1")
    stats = import_delta(relay, bundle)
    assert stats.bytes_payload == 512            # only the changed chunk
    assert relay.fan.ok
    for s in relay.all_stores():
        assert s.verify_image("app", "v2", deep=True) == []
        assert np.array_equal(s.load_image_payload("app", "v2")["b.py"],
                              src2["b.py"])
