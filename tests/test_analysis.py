"""Fixture-driven proof of each analyzer rule (R1-R5) plus the committed
self-scan gate: every rule must flag its violating fixture tree, stay
silent on the clean twin, and a full run over src/repro must diff clean
against the committed ``analysis/baseline.json`` — the same invocation CI
runs (``python -m repro.analysis --check``)."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import RULES, AnalysisConfig, run_analysis
from repro.analysis.baseline import diff, load_baseline

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: anchors (or anchor prefixes — R5's unlocked anchor embeds a line
#: number) every violating fixture must produce, and nothing but these
EXPECTED_ANCHORS = {
    "R1": {"chaos-missing:wire.recv", "test-missing:wire.recv",
           "dead-spec:ghost.point"},
    "R2": {"swallow:pull"},
    "R3": {"undominated-write:publish"},
    "R4": {"unleased-retention:cleanup:remove_image"},
    "R5": {"stale-holdings:LayerStore.remove_tag",
           "unlocked-holdings:LayerStore.note_holding"},
}


def fixture_cfg(name: str) -> AnalysisConfig:
    root = os.path.join(FIXTURES, name)
    tests = os.path.join(root, "tests")
    chaos = os.path.join(root, "chaos.py")
    return AnalysisConfig(
        src_root=os.path.join(root, "src"),
        display_root=root,
        tests_root=tests if os.path.isdir(tests) else None,
        chaos_path=chaos if os.path.exists(chaos) else None,
    )


def run_rule(name: str, rule: str):
    return run_analysis(fixture_cfg(name), rules=(rule,))


def test_rule_registry_complete():
    assert sorted(RULES) == ["R1", "R2", "R3", "R4", "R5"]
    for rule in RULES.values():
        assert rule.contract and rule.motivation and rule.severity


@pytest.mark.parametrize("rule", sorted(EXPECTED_ANCHORS))
def test_rule_flags_violating_fixture(rule):
    findings = run_rule(f"{rule.lower()}_bad", rule)
    anchors = {f.anchor for f in findings}
    for want in EXPECTED_ANCHORS[rule]:
        assert any(a == want or a.startswith(want + ":") for a in anchors), \
            f"{rule} missed {want!r}; got {sorted(anchors)}"
    for got in anchors:
        assert any(got == w or got.startswith(w + ":")
                   for w in EXPECTED_ANCHORS[rule]), \
            f"{rule} over-reported {got!r}"
    assert all(f.rule == rule for f in findings)


@pytest.mark.parametrize("rule", sorted(EXPECTED_ANCHORS))
def test_rule_passes_clean_fixture(rule):
    findings = run_rule(f"{rule.lower()}_clean", rule)
    assert findings == [], [f.render() for f in findings]


def test_fingerprints_anchor_not_line():
    """Suppressions must survive unrelated line drift: the fingerprint is
    a function of (rule, path, anchor) only."""
    a = run_rule("r2_bad", "R2")
    assert len(a) == 1
    f = a[0]
    clone = type(f)(f.rule, f.severity, f.path, f.line + 40, f.anchor,
                    "different message")
    assert clone.fingerprint == f.fingerprint


def test_self_scan_matches_committed_baseline():
    """The acceptance gate itself, in-process: a full 5-rule run over
    src/repro must produce no finding that is not a reasoned suppression
    in the committed baseline (and no stale/unreasoned entries)."""
    cfg = AnalysisConfig.for_repo()
    findings = run_analysis(cfg)
    baseline = load_baseline(cfg.baseline_path)
    new, _suppressed, stale, unreasoned = diff(findings, baseline)
    assert new == [], [f.render() for f in new]
    assert stale == [] and unreasoned == []


def test_repo_fault_point_coverage_is_closed():
    """R1 over the real tree: every fault_point has chaos + test coverage
    and no live spec is dead — the only tolerated findings are the two
    suppressed synthetic points of the nested-injector test. Deleting a
    chaos seam or a fault point breaks this (and CI) immediately."""
    cfg = AnalysisConfig.for_repo()
    findings = run_analysis(cfg, rules=("R1",))
    anchors = {f.anchor for f in findings}
    assert anchors <= {"dead-spec:x", "dead-spec:y"}, sorted(anchors)


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT)


def test_cli_check_fails_on_violating_tree(tmp_path):
    out_json = str(tmp_path / "findings.json")
    r = _cli("--root", os.path.join(FIXTURES, "r2_bad"), "--rules", "R2",
             "--check", "--json", out_json)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "NEW" in r.stdout and "swallow:pull" in r.stdout
    report = json.load(open(out_json))
    assert report["new"] and report["findings"]


def test_cli_check_passes_clean_tree_and_repo():
    r = _cli("--root", os.path.join(FIXTURES, "r2_clean"), "--rules",
             "R2", "--check")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "check: clean" in r.stdout
    full = _cli("--check")
    assert full.returncode == 0, full.stdout + full.stderr
    assert "check: clean" in full.stdout


def test_cli_explain_every_rule():
    for rule_id in RULES:
        r = _cli("--explain", rule_id)
        assert r.returncode == 0
        assert "CONTRACT" in r.stdout and "MOTIVATING BUG" in r.stdout
    assert _cli("--explain", "R9").returncode == 2
