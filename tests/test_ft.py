"""Fault tolerance: crash atomicity, restart-resume, straggler, watchdog."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.ft import DeadlineSkipper, Watchdog, shrink_mesh_shape


def tiny_state(key):
    params = {"w": jax.random.normal(key, (32, 32))}
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    return params, opt


def test_crash_mid_save_preserves_previous(tmp_path):
    """A save that dies before manifest commit leaves the old ckpt valid."""
    params, opt = tiny_state(jax.random.PRNGKey(0))
    pol = CheckpointPolicy(incremental=False, async_write=False,
                           chunk_bytes=128)
    mgr = CheckpointManager(str(tmp_path), "t", pol)
    mgr.save(0, params, opt)

    class Boom(RuntimeError):
        pass

    # simulate crash: a provider that writes some blobs then raises —
    # build_image dies before write_image (the manifest commit point).
    # params changed => fall-through reaches the dying RUN provider.
    params2 = {"w": params["w"] + 1.0}
    payloads = mgr._payloads(params2, opt, 1)
    ins = mgr._instructions()

    def dying_provider():
        raise Boom()

    providers = {k: (lambda v=v: v) for k, v in payloads.items()}
    providers["opt_state"] = dying_provider
    with pytest.raises(Boom):
        mgr.store.build_image("ckpt", mgr.tag_of(1), ins, providers,
                              parent=("ckpt", mgr.tag_of(0)))
    # previous checkpoint untouched & valid
    assert mgr.latest_step() == 0
    assert mgr.store.verify_image("ckpt", mgr.tag_of(0)) == []
    out = mgr.restore()
    assert out is not None and out[2] == 0


def test_restart_resume_bitwise(tmp_path):
    """Save -> new manager (fresh process analogue) -> restore bitwise."""
    params, opt = tiny_state(jax.random.PRNGKey(1))
    mgr = CheckpointManager(str(tmp_path), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=128))
    mgr.save(7, params, opt)
    mgr2 = CheckpointManager(str(tmp_path), "t",
                             CheckpointPolicy(async_write=False,
                                              chunk_bytes=128))
    p2, o2, step = mgr2.restore()
    assert step == 7
    assert np.array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))


def test_straggler_skip_and_cordon():
    sk = DeadlineSkipper(n_hosts=4, factor=2.0, cordon_after=2)
    # host 3 is persistently 10x slower
    for _ in range(3):
        inc = sk.decide({0: 1.0, 1: 1.1, 2: 0.9, 3: 10.0})
    assert inc[0] and inc[1] and inc[2] and not inc[3]
    assert 3 in sk.stats.cordoned
    w = sk.contribution_weights(inc)
    assert w[3] == 0.0
    assert w[0] == pytest.approx(4 / 3)


def test_straggler_recovers():
    sk = DeadlineSkipper(n_hosts=2, factor=2.0, cordon_after=5)
    sk.decide({0: 1.0, 1: 5.0})
    inc = sk.decide({0: 1.0, 1: 1.0})
    assert inc[1]
    assert sk.consecutive[1] == 0


def test_watchdog_fires_and_disarms():
    fired = []
    wd = Watchdog(0.05, lambda: fired.append(1))
    wd.arm()
    time.sleep(0.15)
    assert fired == [1]
    wd2 = Watchdog(0.2, lambda: fired.append(2))
    with wd2:
        time.sleep(0.02)
    time.sleep(0.25)
    assert fired == [1]                  # disarmed in time


def test_watchdog_disarm_fire_race():
    """Regression: a timer firing CONCURRENTLY with disarm() must not run
    on_timeout or set ``fired`` after disarm returns. With a near-zero
    timeout the timer thread races every disarm; the generation token
    makes the disarm win deterministically. Hammered many rounds — before
    the lock+token fix this flaked within a few hundred iterations."""
    late = []
    for i in range(300):
        wd = Watchdog(1e-4, lambda i=i: late.append(i))
        wd.arm()
        wd.disarm()
        # once disarm() returned, the contract is final: no late callback,
        # no late flag — even though the Timer thread may still be alive
        assert not wd.fired, f"round {i}: fired set after disarm returned"
    time.sleep(0.05)                     # let any stale timers drain
    assert late == [], f"on_timeout ran after disarm: rounds {late[:5]}"


def test_watchdog_rearm_generation_isolation():
    """arm() after a pending fire must fence the OLD timer: only the new
    generation may fire, and a genuine timeout still works."""
    fired = []
    wd = Watchdog(1e-4, lambda: fired.append("old"))
    wd.arm()
    wd.disarm()
    wd.timeout = 0.05
    wd.on_timeout = lambda: fired.append("new")
    wd.arm()
    time.sleep(0.15)
    assert fired == ["new"] and wd.fired


def test_shrink_mesh_shape():
    assert shrink_mesh_shape(256, model=16) == (16, 16)
    assert shrink_mesh_shape(240, model=16) == (15, 16)
    assert shrink_mesh_shape(512, model=16, pods=2) == (2, 16, 16)
    assert shrink_mesh_shape(8, model=16) == (1, 16)   # degenerate floor


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint saved 'on' one layout restores onto another (values equal)."""
    from repro.ckpt import reshard_restore
    params, opt = tiny_state(jax.random.PRNGKey(2))
    mgr = CheckpointManager(str(tmp_path), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=128))
    mgr.save(3, params, opt)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    out = reshard_restore(mgr, mesh, {"w": P()}, None)
    assert out is not None
    p2, o2, step = out
    assert step == 3
    assert np.array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
