"""The fused save pipeline: packed whole-tree fingerprints (bit-identical
to the per-leaf oracle), zero-copy chunking, range serialization, the
fingerprint-prefiltered diff, and durability="batch" crash safety."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, chunk_tensor,
                        diff_layer_host, fingerprint_chunks_ref,
                        fingerprint_tree, fingerprint_tree_packed,
                        iter_chunks, tensor_chunk_bytes, tensor_to_bytes)
from repro.core.diff import diff_layer_fingerprint
from repro.core.fingerprint import fingerprint_tree_ref


def _mixed_tree():
    import ml_dtypes
    rng = np.random.default_rng(7)
    return {
        "f32": rng.standard_normal(5000).astype(np.float32),       # ragged
        "f32_exact": rng.standard_normal(1024).astype(np.float32),  # aligned
        "bf16": rng.standard_normal(777).astype(ml_dtypes.bfloat16),
        "i8": rng.integers(-100, 100, 3333).astype(np.int8),
        "bool": rng.standard_normal(1000) > 0,
        "i64": rng.integers(-5, 5, 300).astype(np.int64),
        "f64": rng.standard_normal(129),
        "empty": np.zeros((0,), np.float32),
        "scalar": np.float32(3.5),
        "matrix": rng.standard_normal((64, 48)).astype(np.float32),
    }


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_packed_tree_bit_identical_to_oracle(backend):
    tree = _mixed_tree()
    stats = {}
    got = fingerprint_tree_packed(tree, 1024, backend=backend,
                                  interpret=True, stats=stats)
    for name, v in tree.items():
        ref = fingerprint_chunks_ref(np.asarray(v), 1024)
        assert np.array_equal(got[name], ref), name
    assert stats["device_dispatches"] == 1
    assert stats["bytes_d2h"] == sum(v.nbytes for v in got.values())


def test_packed_matches_per_leaf_and_ref_tree():
    tree = _mixed_tree()
    packed = fingerprint_tree_packed(tree, 512)
    per_leaf = fingerprint_tree(tree, 512)
    oracle = fingerprint_tree_ref(tree, 512)
    for name in tree:
        assert np.array_equal(packed[name], per_leaf[name]), name
        assert np.array_equal(packed[name], oracle[name]), name


def test_packed_empty_tree():
    assert fingerprint_tree_packed({}, 1024) == {}


def test_iter_chunks_memoryview_byte_identical():
    rng = np.random.default_rng(0)
    data = rng.bytes(10_000)
    pieces = list(iter_chunks(data, 1024))
    assert all(isinstance(p, memoryview) for p in pieces)
    old = [data[off:off + 1024] for off in range(0, len(data), 1024)]
    assert [bytes(p) for p in pieces] == old
    # empty input still yields exactly one (empty) chunk
    empty = list(iter_chunks(b"", 1024))
    assert len(empty) == 1 and bytes(empty[0]) == b""


@pytest.mark.parametrize("dtype", ["float32", "int8", "bfloat16", "int64"])
def test_tensor_chunk_bytes_matches_full_serialization(dtype):
    import ml_dtypes
    rng = np.random.default_rng(1)
    arr = rng.standard_normal(3000)
    arr = arr.astype(ml_dtypes.bfloat16 if dtype == "bfloat16" else dtype)
    full = tensor_to_bytes(arr)
    cb = 512
    n_chunks = max(1, -(-len(full) // cb))
    for i in range(n_chunks):
        assert tensor_chunk_bytes(arr, i, cb) == full[i * cb:(i + 1) * cb], i


def test_chunk_tensor_zero_copy_pairs_roundtrip():
    rng = np.random.default_rng(2)
    arr = rng.standard_normal(2000).astype(np.float32)
    rec, pairs = chunk_tensor("x", arr, 512)
    data = b"".join(bytes(p) for _, p in pairs)
    assert data == tensor_to_bytes(arr)
    from repro.core import sha256_hex
    assert [h for h, _ in pairs] == [sha256_hex(bytes(p)) for _, p in pairs]


def _layer_for(store, payload):
    ins = [Instruction("FROM", "b", "config"),
           Instruction("COPY", "data", "content")]
    m, _, _ = store.build_image("m", "v1", ins, {"data": lambda: payload})
    return store.read_layer(m.layer_ids[1])


def test_fingerprint_diff_matches_host_diff(tmp_path):
    rng = np.random.default_rng(3)
    payload = {"a": rng.standard_normal(4000).astype(np.float32),
               "b": rng.standard_normal(100).astype(np.float32)}
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    layer = _layer_for(store, payload)
    new = {k: v.copy() for k, v in payload.items()}
    new["a"][0] += 1.0
    new["a"][2000] += 1.0
    old_fps = fingerprint_tree_ref(payload, 512)
    new_fps = fingerprint_tree_ref(new, 512)
    d_fp = diff_layer_fingerprint(layer, new, old_fps, new_fps)
    d_host = diff_layer_host(layer, new)
    assert sorted([(e.tensor, e.index, e.new_hash, bytes(e.data))
                   for e in d_fp.edits]) == \
        sorted([(e.tensor, e.index, e.new_hash, bytes(e.data))
                for e in d_host.edits])
    assert d_fp.chunks_prefiltered > 0


def test_fingerprint_diff_falls_back_without_history(tmp_path):
    rng = np.random.default_rng(4)
    payload = {"a": rng.standard_normal(1000).astype(np.float32)}
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    layer = _layer_for(store, payload)
    new = {"a": payload["a"].copy()}
    new["a"][1] += 1.0
    # no fingerprints recorded for "a": per-tensor host fallback
    d = diff_layer_fingerprint(layer, new, {}, {})
    assert len(d.edits) == 1 and d.edits[0].index == 0


def test_fingerprint_diff_geometry_mismatch_falls_back(tmp_path):
    """Fingerprints computed with a different chunk size than the stored
    records must not silently drop edits — the diff falls back to the
    full host compare for that tensor."""
    rng = np.random.default_rng(8)
    payload = {"a": rng.standard_normal(2000).astype(np.float32)}
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    layer = _layer_for(store, payload)
    new = {"a": payload["a"].copy()}
    new["a"][-1] += 1.0                       # edit in the LAST chunk
    old_fps = fingerprint_tree_ref(payload, 256)   # wrong chunk size
    new_fps = fingerprint_tree_ref(new, 256)
    d = diff_layer_fingerprint(layer, new, old_fps, new_fps)
    host = diff_layer_host(layer, new)
    assert [(e.tensor, e.index, e.new_hash) for e in d.edits] == \
        [(e.tensor, e.index, e.new_hash) for e in host.edits]
    assert d.edits                            # the edit was NOT dropped


def test_batch_durability_crash_safety(tmp_path):
    """durability="batch": the manifest rename stays the commit point — a
    crash before write_image leaves the previous image fully intact."""
    rng = np.random.default_rng(5)
    payload = {"a": rng.standard_normal(4000).astype(np.float32)}
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512,
                       durability="batch")
    ins = [Instruction("FROM", "b", "config"),
           Instruction("COPY", "data", "content")]
    store.build_image("m", "v1", ins, {"data": lambda: payload})
    assert store.verify_image("m", "v1") == []

    # "crash" mid-save: blobs/layers written, commit never reached
    new = {"a": payload["a"] + 1.0}
    real_write_image = store.write_image
    store.write_image = lambda *a, **k: (_ for _ in ()).throw(
        OSError("power loss"))
    with pytest.raises(OSError):
        store.build_image("m", "v2", ins, {"data": lambda: new},
                          parent=("m", "v1"))
    store.write_image = real_write_image
    # previous image untouched and verifiable; v2 never became visible
    assert store.verify_image("m", "v1") == []
    assert not store.has_image("m", "v2")
    assert store.list_tags("m") == ["v1"]
    # a completed batch-mode save verifies end to end
    store.build_image("m", "v2", ins, {"data": lambda: new},
                      parent=("m", "v1"))
    assert store.verify_image("m", "v2") == []


def test_batch_durability_defers_fsyncs_to_commit(tmp_path):
    """batch mode: no fsync on the write path; everything (file data +
    dirs) flushes in one concurrent batch at the commit point."""
    from repro.core import sha256_hex
    data = b"x" * 1024
    h = sha256_hex(data)
    full = LayerStore(str(tmp_path / "full"), chunk_bytes=512,
                      durability="full")
    full.write_blob(h, data)
    assert full.fsyncs == 1              # synced inline
    batch = LayerStore(str(tmp_path / "batch"), chunk_bytes=512,
                       durability="batch")
    batch.write_blob(h, data)
    assert batch.fsyncs == 0             # deferred
    batch.sync_for_commit()
    assert batch.fsyncs == 2             # blob file data + its directory
    batch.sync_for_commit()
    assert batch.fsyncs == 2             # idempotent: nothing dirty left


def test_list_tags_skips_hex_config_ids(tmp_path):
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    ins = [Instruction("FROM", "b", "config")]
    store.build_image("m", "sometag", ins, {})
    d = os.path.join(store.root, "images", "m")
    files = os.listdir(d)
    # the config blob (32-hex uuid) is on disk but not listed as a tag
    assert any(len(f) == 37 for f in files)
    assert store.list_tags("m") == ["sometag"]


def test_manager_packed_fingerprint_save_equivalent(tmp_path):
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    params = {"embed": jnp.arange(512, dtype=jnp.float32).reshape(64, 8),
              "blocks": {"w": jnp.ones((4, 8, 8), jnp.float32)},
              "head": jnp.zeros((8,), jnp.float32)}
    opt = {"step": jnp.int32(0)}
    mgr = CheckpointManager(
        str(tmp_path), "tiny",
        CheckpointPolicy(incremental=True, use_fingerprints=True,
                         packed_fingerprints=True, async_write=False,
                         chunk_bytes=256, durability="batch"))
    mgr.save(0, params, opt)
    p2 = dict(params)
    p2["embed"] = params["embed"].at[5, 2].add(3.0)
    rep = mgr.save(1, p2, opt)
    assert rep.bytes_d2h > 0
    assert rep.chunks_prefiltered > 0
    out = mgr.restore()
    assert out is not None
    p3, _, step = out
    assert step == 1
    assert np.array_equal(np.asarray(p3["embed"]), np.asarray(p2["embed"]))
    assert np.array_equal(np.asarray(p3["blocks"]["w"]),
                          np.asarray(params["blocks"]["w"]))
