"""Multi-layer batched injection: one re-key walk, one commit, per-layer
cost attribution, crash atomicity, sidecar survival."""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, StructureChangeError,
                        diff_image, fingerprint_chunks_ref, inject_image,
                        inject_image_multi)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "embed", "content"),
    Instruction("COPY", "blocks", "content"),
    Instruction("COPY", "head", "content"),
    Instruction("RUN", "opt", "content",
                derives_from=["embed", "blocks", "head"]),
    Instruction("RUN", "deps", "content"),            # independent
    Instruction("CMD", "run", "config"),
]


def make_payloads(rng):
    return {
        "embed": {"w": rng.standard_normal(1000).astype(np.float32)},
        "blocks": {"w": rng.standard_normal(4000).astype(np.float32)},
        "head": {"w": rng.standard_normal(500).astype(np.float32)},
        "opt": {"m": np.zeros(100, np.float32)},
        "deps": {"lib": rng.standard_normal(800).astype(np.float32)},
    }


def build_v1(store, payloads):
    prov = {k: (lambda v=v: v) for k, v in payloads.items()}
    store.build_image("app", "v1", INS, prov)


def edit_payloads(payloads, keys):
    out = {k: {n: a.copy() for n, a in v.items()}
           for k, v in payloads.items()}
    for i, key in enumerate(keys):
        name = next(iter(out[key]))
        out[key][name][i % out[key][name].size] += 1.0 + i
    return out


def layer_diffs(store, tag, payloads):
    m, _ = store.read_image("app", tag)
    layers = [store.read_layer(lid) for lid in m.layer_ids]
    return diff_image(layers, payloads)


def image_bytes(store, tag):
    return {k: v.tobytes()
            for k, v in store.load_image_payload("app", tag).items()}


def image_chains(store, tag):
    m, c = store.read_image("app", tag)
    return ([c.layer_checksums[lid] for lid in m.layer_ids],
            [c.layer_chains[lid] for lid in m.layer_ids])


def test_batched_equals_sequential_bit_identical(tmp_path, rng):
    payloads = make_payloads(rng)
    new = edit_payloads(payloads, ["embed", "blocks", "head"])
    providers = {k: (lambda v=v: v) for k, v in new.items()}

    store_b = LayerStore(str(tmp_path / "b"), chunk_bytes=512)
    build_v1(store_b, payloads)
    diffs = layer_diffs(store_b, "v1", {k: new[k]
                                        for k in ("embed", "blocks", "head")})
    assert len(diffs) == 3
    inject_image_multi(store_b, "app", "v1", "v2", diffs,
                       providers=providers)

    store_s = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    build_v1(store_s, payloads)
    tag = "v1"
    for i, key in enumerate(("embed", "blocks", "head")):
        d = layer_diffs(store_s, tag, {key: new[key]})
        next_tag = f"v1_{i}" if i < 2 else "v2"
        inject_image(store_s, "app", tag, next_tag, d,
                     providers=providers)
        tag = next_tag

    # bit-identical final content, checksums and chain checksums (layer
    # ids are fresh uuids on both sides and legitimately differ)
    assert image_bytes(store_b, "v2") == image_bytes(store_s, "v2")
    assert image_chains(store_b, "v2") == image_chains(store_s, "v2")
    assert store_b.verify_image("app", "v2") == []
    assert store_s.verify_image("app", "v2") == []
    # the same chunk blobs exist on both sides (content-addressed)
    def blobs(store, tag):
        m, _ = store.read_image("app", tag)
        return {h for lid in m.layer_ids
                for r in store.read_layer(lid).records for h in r.chunks}
    assert blobs(store_b, "v2") == blobs(store_s, "v2")


def test_counters_prove_single_walk_and_commit(tmp_path, rng):
    k = 8
    ins = [Instruction("FROM", "base", "config")]
    payloads = {}
    for i in range(k):
        key = f"layer{i}"
        ins.append(Instruction("COPY", key, "content"))
        payloads[key] = {"w": rng.standard_normal(600).astype(np.float32)}
    ins.append(Instruction("CMD", "run", "config"))

    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    prov = {key: (lambda v=v: v) for key, v in payloads.items()}
    store.build_image("app", "v1", ins, prov)
    new = edit_payloads(payloads, list(payloads))
    diffs = layer_diffs(store, "v1", new)
    assert len(diffs) == k
    _, _, rep = inject_image_multi(store, "app", "v1", "v2", diffs)

    assert rep.rekey_walks == 1
    assert rep.manifest_commits == 1
    assert rep.layers_injected == k
    assert rep.layers_rekeyed == 1          # only the trailing CMD layer
    # per-layer attribution: each targeted layer paid exactly its own
    # edit; the re-keyed CMD layer shows up with a pure re-key entry
    m1, _ = store.read_image("app", "v1")
    cmd_lid = m1.layer_ids[-1]
    assert set(rep.per_layer) == set(diffs) | {cmd_lid}
    assert rep.per_layer[cmd_lid] == {"chunks_written": 0,
                                      "bytes_written": 0, "rekeyed": 1,
                                      "rederived": 0}
    for lid, d in diffs.items():
        assert rep.per_layer[lid]["chunks_written"] == len(d.edits)
        assert rep.per_layer[lid]["bytes_written"] == \
            sum(len(e.data) for e in d.edits)
        assert rep.per_layer[lid]["rekeyed"] == 0
        assert rep.per_layer[lid]["rederived"] == 0
    # the batch's attribution also lands in the image's own history
    _, cfg = store.read_image("app", "v2")
    assert cfg.history[-1]["instruction"] == "INJECT"
    assert set(cfg.history[-1]["per_layer"]) == set(diffs) | {cmd_lid}

    # sequential baseline: k walks, k commits
    store2 = LayerStore(str(tmp_path / "s2"), chunk_bytes=512)
    store2.build_image("app", "v1", ins, prov)
    walks = commits = 0
    tag = "v1"
    for i, key in enumerate(payloads):
        d = layer_diffs(store2, tag, {key: new[key]})
        _, _, r = inject_image(store2, "app", tag, f"v2_{i}", d)
        walks += r.rekey_walks
        commits += r.manifest_commits
        tag = f"v2_{i}"
    assert walks == k
    assert commits == k


def test_shared_downstream_rederived_exactly_once(tmp_path, rng):
    payloads = make_payloads(rng)
    new = edit_payloads(payloads, ["embed", "blocks", "head"])
    calls = {"opt": 0, "deps": 0}

    def opt_provider():
        calls["opt"] += 1
        return new["opt"]

    def deps_provider():
        calls["deps"] += 1
        return new["deps"]

    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    build_v1(store, payloads)
    diffs = layer_diffs(store, "v1", {k: new[k]
                                      for k in ("embed", "blocks", "head")})
    _, _, rep = inject_image_multi(
        store, "app", "v1", "v2", diffs,
        providers={"opt": opt_provider, "deps": deps_provider})
    # three upstream injections hit `opt` — it re-derives ONCE; `deps`
    # has no derives_from edge and is only re-keyed
    assert calls == {"opt": 1, "deps": 0}
    assert rep.derivations_run == 1
    m1, _ = store.read_image("app", "v1")
    opt_lid, deps_lid = m1.layer_ids[4], m1.layer_ids[5]
    assert rep.per_layer[opt_lid]["rederived"] == 1
    assert rep.per_layer[deps_lid] == {"chunks_written": 0,
                                       "bytes_written": 0, "rekeyed": 1,
                                       "rederived": 0}
    assert store.verify_image("app", "v2") == []

    # sequential: every single-layer injection re-derives the shared
    # downstream again — 3 derivations for the same end state
    store2 = LayerStore(str(tmp_path / "s2"), chunk_bytes=512)
    build_v1(store2, payloads)
    seq_calls = {"n": 0}

    def opt_provider2():
        seq_calls["n"] += 1
        return new["opt"]

    tag = "v1"
    for i, key in enumerate(("embed", "blocks", "head")):
        d = layer_diffs(store2, tag, {key: new[key]})
        inject_image(store2, "app", tag, f"v2_{i}", d,
                     providers={"opt": opt_provider2,
                                "deps": deps_provider})
        tag = f"v2_{i}"
    assert seq_calls["n"] == 3


def test_validation_aborts_batch_before_any_write(tmp_path, rng):
    payloads = make_payloads(rng)
    new = edit_payloads(payloads, ["embed", "blocks"])
    new["blocks"]["extra"] = np.ones(10, np.float32)   # structure change
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    build_v1(store, payloads)
    diffs = layer_diffs(store, "v1", {k: new[k]
                                      for k in ("embed", "blocks")})

    def count_blobs():
        return sum(len(fs) for _, _, fs in
                   os.walk(os.path.join(store.root, "blobs")))

    before = count_blobs()
    with pytest.raises(StructureChangeError):
        inject_image_multi(store, "app", "v1", "v2", diffs)
    # the valid embed edit was NOT partially applied: zero new blobs
    assert count_blobs() == before
    assert not store.has_image("app", "v2")

    with pytest.raises(KeyError):
        inject_image_multi(store, "app", "v1", "v2",
                           {"nonexistent": diffs[next(iter(diffs))]})

    # a missing Scenario-4 provider is also caught before any write: the
    # injected layers sit upstream of `opt` (derives_from) and no
    # provider is supplied
    del new["blocks"]["extra"]
    diffs = layer_diffs(store, "v1", {k: new[k]
                                      for k in ("embed", "blocks")})
    with pytest.raises(StructureChangeError):
        inject_image_multi(store, "app", "v1", "v2", diffs)
    assert count_blobs() == before
    assert not store.has_image("app", "v2")


def test_kill9_mid_batch_previous_image_intact(tmp_path):
    """A literal SIGKILL between the batched blob writes and the manifest
    commit (durability="batch", so nothing was fsync'd yet) must leave the
    previous image fully verifiable and the new tag invisible."""
    root = str(tmp_path / "store")
    script = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.core import Instruction, LayerStore, diff_image, \\
            inject_image_multi

        ins = [Instruction("FROM", "base", "config"),
               Instruction("COPY", "src", "content"),
               Instruction("RUN", "build", "content",
                           derives_from=["src"])]
        payloads = {{"src": {{"w": np.arange(2000, dtype=np.float32)}},
                     "build": {{"b": np.ones(500, np.float32)}}}}
        store = LayerStore({root!r}, chunk_bytes=256, durability="batch")
        prov = {{k: (lambda v=v: v) for k, v in payloads.items()}}
        store.build_image("app", "v1", ins, prov)
        print("BUILT", flush=True)

        new = {{"src": {{"w": payloads["src"]["w"] + 1.0}}}}
        m, _ = store.read_image("app", "v1")
        layers = [store.read_layer(l) for l in m.layer_ids]
        diffs = diff_image(layers, new)

        def dying_provider():
            # blobs + cloned layer already written (un-synced), commit not
            # reached: die the hard way, no atexit, no cleanup
            os.kill(os.getpid(), signal.SIGKILL)

        inject_image_multi(store, "app", "v1", "v2", diffs,
                           providers={{"build": dying_provider}})
        print("UNREACHABLE", flush=True)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr)
    assert "BUILT" in r.stdout
    assert "UNREACHABLE" not in r.stdout

    store = LayerStore(root, chunk_bytes=256)
    assert store.verify_image("app", "v1") == []
    assert not store.has_image("app", "v2")
    assert store.list_tags("app") == ["v1"]


def test_fingerprint_sidecar_survives_injection(tmp_path, rng):
    """apply_edits must refresh TensorRecord.fp on cloned records so the
    next build_image COPY check stays a prefilter (ROADMAP open item)."""
    payloads = make_payloads(rng)
    new = edit_payloads(payloads, ["embed", "blocks"])
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512,
                       record_fingerprints=True)
    build_v1(store, payloads)
    diffs = layer_diffs(store, "v1", {k: new[k]
                                      for k in ("embed", "blocks")})
    inject_image_multi(store, "app", "v1", "v2", diffs,
                       providers={k: (lambda v=v: v) for k, v in
                                  new.items()})

    m2, _ = store.read_image("app", "v2")
    for lid, key in zip(m2.layer_ids[1:4], ("embed", "blocks", "head")):
        layer = store.read_layer(lid, use_cache=False)
        for rec in layer.records:
            assert rec.fp is not None, (key, rec.name)
            want = fingerprint_chunks_ref(
                np.asarray(new[key][rec.name]), rec.chunk_bytes)
            assert rec.fp == tuple((int(a), int(b))
                                   for a, b in want.tolist())

    # and the COPY cache check on the injected image is answered by the
    # sidecar: full hit, zero bytes re-hashed
    prov = {k: (lambda v=v: v) for k, v in new.items()}
    _, _, rep = store.build_image("app", "v3", INS, prov,
                                  parent=("app", "v2"))
    assert rep.layers_built == 0
    assert rep.chunks_prefiltered > 0
    assert rep.bytes_hashed == 0


def test_misaligned_chunk_size_drops_sidecar_not_crash(tmp_path, rng):
    """chunk_bytes not a multiple of the itemsize: no per-chunk fp can
    match the whole-tensor table, so injection drops the sidecar (and
    stays correct) instead of crashing in the refresh path."""
    payload = {"w": rng.standard_normal(500).astype(np.float64)}
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=1001,
                       record_fingerprints=True)
    ins = [Instruction("FROM", "b", "config"),
           Instruction("COPY", "data", "content")]
    store.build_image("app", "v1", ins, {"data": lambda: payload})
    new = {"data": {"w": payload["w"].copy()}}
    new["data"]["w"][3] += 1.0
    diffs = layer_diffs(store, "v1", new)
    inject_image_multi(store, "app", "v1", "v2", diffs)
    assert store.verify_image("app", "v2") == []
    m2, _ = store.read_image("app", "v2")
    layer = store.read_layer(m2.layer_ids[1], use_cache=False)
    assert all(r.fp is None for r in layer.records)
    loaded = store.load_image_payload("app", "v2")
    assert np.array_equal(loaded["w"], new["data"]["w"])


def test_empty_batch_is_a_cheap_retag(tmp_path, rng):
    payloads = make_payloads(rng)
    store = LayerStore(str(tmp_path / "s"), chunk_bytes=512)
    build_v1(store, payloads)
    _, _, rep = inject_image_multi(store, "app", "v1", "v2", {})
    assert rep.layers_injected == 0
    assert rep.chunks_written == 0
    assert rep.manifest_commits == 1
    assert image_chains(store, "v2") == image_chains(store, "v1")
