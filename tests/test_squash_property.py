"""Generative proof of the squash contract: for ANY run of per-commit
injections, applying the single squashed bundle is indistinguishable —
manifest, config locks, chunk bytes — from replaying every per-commit
delta in sequence."""
import shutil
import tempfile

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import max_examples  # noqa: E402
from repro.core import (Instruction, LayerStore, encode_delta,
                        import_delta, inject_payload_update, push,
                        squash_deltas)  # noqa: E402

INS = [Instruction("FROM", "arch", "config"),
       Instruction("COPY", "state", "content"),
       Instruction("COPY", "extra", "content"),
       Instruction("CMD", "serve", "config")]

N_CHUNKS = 8
FLOATS_PER_CHUNK = 128                      # 512 B chunks


def tag(s):
    return f"step-{s:08d}"


def snapshot(store, name, t):
    manifest, config = store.read_image(name, t)
    blobs = {h: store.read_blob(h)
             for lid in manifest.layer_ids
             for rec in store.read_layer(lid).records
             for h in rec.chunks}
    return manifest.to_json(), config.layer_checksums, blobs


# each hop: which chunks of 'state' to rewrite (possibly none — a pure
# re-key hop) and whether to touch the second leaf too
hop_st = st.tuples(
    st.lists(st.integers(0, N_CHUNKS - 1), max_size=3, unique=True),
    st.booleans())


@settings(max_examples=max_examples(25), deadline=None)
@given(hops=st.lists(hop_st, min_size=1, max_size=5),
       seed=st.integers(0, 2**16))
def test_squash_equals_sequential_application(hops, seed):
    rng = np.random.default_rng(seed)
    base = tempfile.mkdtemp(prefix="squash-prop-")
    try:
        src = LayerStore(f"{base}/src", chunk_bytes=512)
        state = {"w": rng.standard_normal(
            N_CHUNKS * FLOATS_PER_CHUNK).astype(np.float32)}
        extra = {"e": rng.standard_normal(64).astype(np.float32)}
        src.build_image("ckpt", tag(0), INS,
                        {"state": lambda: state, "extra": lambda: extra})
        for i, (chunk_ids, touch_extra) in enumerate(hops, start=1):
            state = {"w": state["w"].copy()}
            for c in chunk_ids:
                lo = c * FLOATS_PER_CHUNK
                state["w"][lo:lo + FLOATS_PER_CHUNK] = \
                    rng.standard_normal(FLOATS_PER_CHUNK)
            payload = {"state": state}
            if touch_extra:
                extra = {"e": extra["e"].copy()}
                extra["e"][0] = float(i)
                payload["extra"] = extra
            inject_payload_update(src, "ckpt", tag(i - 1), tag(i), payload)
        head = len(hops)

        seq = LayerStore(f"{base}/seq", chunk_bytes=512)
        sq = LayerStore(f"{base}/sq", chunk_bytes=512)
        for dst in (seq, sq):
            push(src, dst, "ckpt", tag(0))
        for i in range(1, head + 1):        # replay every per-commit hop
            import_delta(seq, encode_delta(
                squash_deltas(src, "ckpt", tag(i - 1), tag(i))))
        import_delta(sq, encode_delta(     # ONE squashed bundle
            squash_deltas(src, "ckpt", tag(0), tag(head))))

        want = snapshot(src, "ckpt", tag(head))
        assert snapshot(seq, "ckpt", tag(head)) == want
        assert snapshot(sq, "ckpt", tag(head)) == want
        assert sq.verify_image("ckpt", tag(head), deep=True) == []
    finally:
        shutil.rmtree(base, ignore_errors=True)
