"""Chaos-hardened replication: deterministic fault injection, in-run
self-healing retries (counter-proved to pay only the un-transferred
remainder), quarantine after bounded attempts, relay retention leases that
survive injected faults, and the batch-durability crash seam."""
import time

import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, RelayNode,
                        inject_payload_update, push_delta, replicate_fanout)
from repro.ft import (CrashInjected, FaultInjected, FaultInjector,
                      FaultSpec, RetryPolicy, inject)
from repro.ft.chaos import run_cell

INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "src", "content"),
    Instruction("RUN", "deps", "content"),
    Instruction("CMD", "run", "config"),
]


def mk(tmp_path, name, **kw):
    return LayerStore(str(tmp_path / name), chunk_bytes=512, **kw)


def make_payloads(rng):
    return {
        "src": {"a": rng.standard_normal(25000).astype(np.float32),
                "b": rng.standard_normal(500).astype(np.float32)},
        "deps": {"lib": rng.standard_normal(4000).astype(np.float32)},
    }


def build_v1(store, payloads):
    store.build_image("app", "v1", INS,
                      {k: (lambda v=v: v) for k, v in payloads.items()})


def inject_v2_wide(store, payloads):
    """v2 changes ~40 separate 512 B chunks of 'src' — wider than one
    32-blob transfer wave, so a fault targeting a wave-2 blob strikes with
    a full wave of partial progress deterministically behind it."""
    src2 = {k: v.copy() for k, v in payloads["src"].items()}
    for idx in range(40):
        src2["a"][idx * 128] = 42.0          # one float per 512 B chunk
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"deps": lambda: payloads["deps"]})


def delta_blob_hashes(src, dst, name, tag):
    """The sorted blob set a push of ``name:tag`` would send ``dst`` — the
    same sorted order the transfer ships in, so index 32+ is in wave 2."""
    manifest, _ = src.read_image(name, tag)
    return sorted({h for lid in manifest.layer_ids
                   for rec in src.read_layer(lid).records
                   for h in rec.chunks if not dst.has_blob(h)})


def snapshot(store, name, tag):
    manifest, config = store.read_image(name, tag)
    layers, blobs = {}, {}
    for lid in manifest.layer_ids:
        with open(store._layer_path(lid), "rb") as f:
            layers[lid] = f.read()
        for rec in store.read_layer(lid).records:
            for h in rec.chunks:
                blobs[h] = store.read_blob(h)
    return {"manifest": manifest.to_json(), "config": config.to_json(),
            "layers": layers, "blobs": blobs}


FAST = dict(max_attempts=3, base_delay_s=0.001, max_delay_s=0.01)


# ------------------------------------------------------- fault injection
def test_fault_points_are_noops_when_uninstalled(tmp_path, rng):
    """No injector installed -> the threaded fault points change nothing:
    a push is bit-identical to one on a build that never imported ft."""
    store, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    push_delta(store, dst, "app", "v1")
    assert dst.verify_image("app", "v1", deep=True) == []
    assert snapshot(dst, "app", "v1") == snapshot(store, "app", "v1")


def test_injector_decisions_are_order_independent():
    """Fire decisions depend on (seed, point, key, nth-hit) — NOT on the
    global arrival order — so pool-thread interleavings can't change which
    hits fire. Same hits in reversed per-key order => same decisions."""
    keys = [f"store-{i}:blob-{j}" for i in range(3) for j in range(4)]

    def decide(order):
        inj = FaultInjector(seed=7, specs=[
            FaultSpec(point="wire.receive_blob", mode="delay",
                      prob=0.5, times=None, delay_s=0.0)])
        for k in order:
            inj.hit("wire.receive_blob", k, b"x")
        return {(e.key, e.hit) for e in inj.log}

    assert decide(keys) == decide(list(reversed(keys)))


def test_corrupt_flips_exactly_one_deterministic_byte():
    inj = FaultInjector(seed=3, specs=[
        FaultSpec(point="wire.receive_blob", mode="corrupt")])
    data = bytes(range(256))
    out1 = inj.hit("wire.receive_blob", "k", data)
    inj2 = FaultInjector(seed=3, specs=[
        FaultSpec(point="wire.receive_blob", mode="corrupt")])
    out2 = inj2.hit("wire.receive_blob", "k", data)
    assert out1 == out2 != data
    assert sum(a != b for a, b in zip(out1, data)) == 1


def test_nested_injector_install_rejected():
    with inject(0, FaultSpec(point="x", mode="drop")):
        with pytest.raises(RuntimeError):
            with inject(1, FaultSpec(point="y", mode="drop")):
                pass


# ------------------------------------------------- retry pays only delta
def test_retry_resumes_from_partial_counter_proved(tmp_path, rng):
    """A drop mid-transfer fails the replica with real partial progress;
    the in-run retry converges it and its books prove the retry paid ONLY
    the remainder: retry payload == full delta − first-attempt payload."""
    store, dst, control = (mk(tmp_path, n) for n in ("src", "dst", "ctl"))
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    for d in (dst, control):
        push_delta(store, d, "app", "v1")
    inject_v2_wide(store, payloads)
    need = delta_blob_hashes(store, dst, "app", "v2")
    assert len(need) >= 35                   # the delta spans two waves
    delta = push_delta(store, control, "app", "v2")   # clean reference
    assert delta.blobs_sent == len(need)

    policy = RetryPolicy(seed=1, **FAST)
    # drop exactly one wave-2 blob: wave 1 (32 blobs) has fully landed —
    # ship+receive barriers per wave — before the fault can strike
    with inject(1, FaultSpec(point="wire.receive_blob", mode="drop",
                             match=need[34])) as inj:
        fan = replicate_fanout(store, [dst], "app", "v2", retry=policy)
    assert inj.fired() == 1
    rep = fan.replicas[0]
    assert rep.ok and rep.health is not None and rep.health.succeeded
    assert rep.health.retries == 1 and fan.retries_spent == 1
    assert fan.quarantined == []
    # the counter-proof. stats_partial keeps the first attempt's books:
    # at least the full first wave landed before the drop.
    assert rep.stats_partial.blobs_sent >= 32
    assert rep.stats.bytes_payload == \
        delta.bytes_payload - rep.stats_partial.bytes_payload
    assert rep.stats.blobs_sent == delta.blobs_sent - \
        rep.stats_partial.blobs_sent
    assert snapshot(dst, "app", "v2") == snapshot(store, "app", "v2")
    assert dst.verify_image("app", "v2", deep=True) == []


def test_quarantine_after_exactly_max_attempts(tmp_path, rng):
    """A persistently-sick replica is retried exactly max_attempts times
    total (injector hit count proves it), then quarantined with the
    structured health record — while the healthy majority commits."""
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    replicas = [mk(tmp_path, f"r{i}") for i in range(3)]
    for r in replicas:
        push_delta(store, r, "app", "v1")
    inject_v2_wide(store, payloads)

    policy = RetryPolicy(seed=0, **FAST)
    with inject(0, FaultSpec(point="wire.negotiate", mode="drop",
                             match=replicas[1].root, times=None)) as inj:
        fan = replicate_fanout(store, replicas, "app", "v2", retry=policy)
    assert inj.fired("wire.negotiate") == policy.max_attempts
    assert fan.quarantined == [1] and fan.n_ok == 2 and fan.majority_ok
    bad = fan.replicas[1]
    assert not bad.ok and bad.health.quarantined
    assert bad.health.attempts == policy.max_attempts
    assert bad.health.retries == policy.max_attempts - 1
    assert len(bad.health.errors) >= policy.max_attempts
    assert not replicas[1].has_image("app", "v2")      # never torn, never
    assert replicas[1].verify_image("app", "v1", deep=True) == []  # committed
    for i in (0, 2):
        assert snapshot(replicas[i], "app", "v2") == \
            snapshot(store, "app", "v2")
    # the sick replica converges on the NEXT cycle once the fault clears
    fan2 = replicate_fanout(store, replicas, "app", "v2")
    assert fan2.ok
    assert snapshot(replicas[1], "app", "v2") == snapshot(store, "app", "v2")


def test_retry_respects_deadline(tmp_path, rng):
    """deadline_s=0 can't contain any backoff sleep: no retry is ever
    attempted, the failure quarantines immediately with the flag set."""
    store, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    policy = RetryPolicy(seed=0, deadline_s=0.0, **FAST)
    with inject(0, FaultSpec(point="wire.commit", mode="drop",
                             match=dst.root, times=None)):
        fan = replicate_fanout(store, [dst], "app", "v1", retry=policy)
    rep = fan.replicas[0]
    assert not rep.ok and rep.health.quarantined
    assert rep.health.deadline_exceeded and rep.health.attempts == 1


def test_crash_mid_commit_retries_to_convergence(tmp_path, rng):
    """CrashInjected at the receiver's commit (death just before the
    manifest rename): previous tag intact, retry adopts the debris and
    the remainder-only accounting still holds (everything landed, so the
    successful attempt re-sends NO payload bytes)."""
    store, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    push_delta(store, dst, "app", "v1")
    inject_v2_wide(store, payloads)
    policy = RetryPolicy(seed=5, **FAST)
    with inject(5, FaultSpec(point="wire.commit", mode="crash",
                             match=dst.root)):
        fan = replicate_fanout(store, [dst], "app", "v2", retry=policy)
    rep = fan.replicas[0]
    assert rep.ok and isinstance(rep.health.errors[0], str)
    assert "CrashInjected" in rep.health.errors[0]
    assert rep.stats.bytes_payload == 0          # all blobs were adopted
    assert rep.stats_partial.bytes_payload > 0   # ...from attempt 1's work
    assert snapshot(dst, "app", "v2") == snapshot(store, "app", "v2")
    assert dst.verify_image("app", "v2", deep=True) == []


# ------------------------------------------------------ retention leases
def test_lease_blocks_remove_until_release_or_expiry(tmp_path, rng):
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    store.acquire_lease("app", "v1", "child-a", ttl_s=60.0)
    store.acquire_lease("app", "v1", "child-b", ttl_s=0.05)
    assert store.lease_holders("app", "v1") == ["child-a", "child-b"]
    assert store.remove_image("app", "v1") is False      # refused
    assert store.has_image("app", "v1")
    assert store.release_lease("app", "child-a") == 1    # ref-counted:
    time.sleep(0.06)                                     # b expires alone
    assert not store.leased("app", "v1")
    assert store.remove_image("app", "v1") is True


def test_lease_force_override_and_gc_safety(tmp_path, rng):
    store = mk(tmp_path, "src")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    inject_v2_wide(store, payloads)
    store.acquire_lease("app", "v1", "child", ttl_s=60.0)
    # gc with the leased tag still present keeps every blob it references
    store.gc()
    assert store.verify_image("app", "v1", deep=True) == []
    assert store.remove_image("app", "v1", force=True) is True
    store.gc()
    assert store.verify_image("app", "v2", deep=True) == []


def test_prune_steps_skips_leased_tags(tmp_path, rng):
    from repro.ckpt.manager import prune_steps
    store = mk(tmp_path, "ckpt")
    state = {"params/w": rng.standard_normal(600).astype(np.float32)}
    ins = [Instruction("FROM", "arch", "config"),
           Instruction("COPY", "state", "content")]
    store.build_image("ckpt", "step-00000001", ins,
                      {"state": lambda: state})
    for step in (2, 3, 4):
        state = {"params/w": state["params/w"].copy()}
        state["params/w"][step] = float(step)
        inject_payload_update(store, "ckpt", f"step-{step - 1:08d}",
                              f"step-{step:08d}", {"state": state})
    store.acquire_lease("ckpt", "step-00000001", "lagging-child",
                        ttl_s=60.0)
    assert prune_steps(store, "ckpt", keep=2)
    tags = set(store.list_tags("ckpt"))
    assert "step-00000001" in tags          # lease held it open
    assert "step-00000002" not in tags      # unleased victim pruned
    assert store.verify_image("ckpt", "step-00000001", deep=True) == []
    store.release_lease("ckpt", "lagging-child")
    assert prune_steps(store, "ckpt", keep=2)
    assert set(store.list_tags("ckpt")) == {"step-00000003",
                                            "step-00000004"}


def test_relay_leases_released_on_child_commit(tmp_path, rng):
    store, mid, e0, e1 = (mk(tmp_path, n)
                          for n in ("src", "mid", "e0", "e1"))
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    relay = RelayNode(mid, children=[e0, e1])
    fan = replicate_fanout(store, [relay], "app", "v1")
    assert fan.ok and fan.replicas[0].children.n_ok == 2
    assert not mid.leased("app", "v1")       # both children committed


def test_relay_dead_child_lease_expires_then_prune_proceeds(tmp_path, rng):
    """The ISSUE's fault-proved lease lifecycle: a child that dies mid-pull
    leaves its lease held (prune refuses the base), the lease expires on
    the deadline, and prune then reclaims — while a LIVE lagging child's
    base tag had survived the whole time."""
    from repro.ckpt.manager import prune_steps
    store, mid, edge = (mk(tmp_path, n) for n in ("src", "mid", "edge"))
    state = {"params/w": rng.standard_normal(600).astype(np.float32)}
    ins = [Instruction("FROM", "arch", "config"),
           Instruction("COPY", "state", "content")]
    store.build_image("ckpt", "step-00000001", ins,
                      {"state": lambda: state})
    relay = RelayNode(mid, children=[edge], lease_ttl_s=0.2)
    fan = replicate_fanout(store, [relay], "ckpt", "step-00000001")
    assert fan.ok and not mid.leased("ckpt", "step-00000001")

    for step in (2, 3):
        state = {"params/w": state["params/w"].copy()}
        state["params/w"][step] = float(step)
        inject_payload_update(store, "ckpt", f"step-{step - 1:08d}",
                              f"step-{step:08d}", {"state": state})
    # the child DIES mid-pull (drop fires at every receive, no retry):
    # the relay itself commits step-2, the child's lease on the relay's
    # base tag (step-1) stays held
    with inject(0, FaultSpec(point="wire.receive_blob", mode="drop",
                             match=edge.root, times=None)):
        fan = replicate_fanout(store, [relay], "ckpt", "step-00000002")
    assert fan.ok                            # relay tier committed
    assert not fan.replicas[0].children.ok   # child did not
    assert mid.leased("ckpt", "step-00000001")
    # prune under load: keep=1 would collect step-1, the lease refuses
    prune_steps(mid, "ckpt", keep=1)
    assert "step-00000001" in mid.list_tags("ckpt")
    assert mid.verify_image("ckpt", "step-00000001", deep=True) == []
    # ...until the dead child's lease expires; then retention reclaims
    time.sleep(0.25)
    assert not mid.leased("ckpt", "step-00000001")
    prune_steps(mid, "ckpt", keep=1)
    assert set(mid.list_tags("ckpt")) == {"step-00000002"}
    # the next healthy cycle converges the once-dead child from scratch
    fan = replicate_fanout(store, [relay], "ckpt", "step-00000003")
    assert fan.ok and fan.replicas[0].children.ok
    assert snapshot(edge, "ckpt", "step-00000003") == \
        snapshot(store, "ckpt", "step-00000003")


def test_relay_child_retry_releases_lease_on_convergence(tmp_path, rng):
    store, mid, edge = (mk(tmp_path, n) for n in ("src", "mid", "edge"))
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    policy = RetryPolicy(seed=2, **FAST)
    relay = RelayNode(mid, children=[edge], retry=policy)
    need = delta_blob_hashes(store, edge, "app", "v1")
    with inject(2, FaultSpec(point="wire.receive_blob", mode="corrupt",
                             match=f"{edge.root}:{need[0]}")):
        fan = replicate_fanout(store, [relay], "app", "v1", retry=policy)
    assert fan.ok and fan.replicas[0].children.n_ok == 1
    assert fan.replicas[0].children.retries_spent == 1
    assert not mid.leased("app", "v1")       # released by on_converged
    assert snapshot(edge, "app", "v1") == snapshot(store, "app", "v1")


# ------------------------------------------- batch-durability crash seam
def test_failed_push_leaves_no_unsynced_adoptable_blobs(tmp_path, rng):
    """The _BatchScope.__exit__ fix: a push that dies mid-batch must flush
    the orphans it strands before restoring durability — otherwise a later
    probe_blobs re-hash adopts blobs whose fsync nobody ever scheduled."""
    store, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    push_delta(store, dst, "app", "v1")
    inject_v2_wide(store, payloads)
    need = delta_blob_hashes(store, dst, "app", "v2")
    with inject(0, FaultSpec(point="wire.receive_blob", mode="crash",
                             match=need[34])) as inj:
        fan = replicate_fanout(store, [dst], "app", "v2")
    assert not fan.ok and inj.fired() == 1
    assert fan.replicas[0].stats_partial.blobs_sent >= 32   # real orphans
    # the crash-mid-batch lock: nothing dirty survives the scope, the
    # landed orphans were flushed on exit, durability mode restored
    assert dst._dirty_files == set() and dst._dirty_dirs == set()
    assert dst.durability == "batch"         # the store's own default


def test_adopted_orphans_are_made_durable_on_full_store(tmp_path, rng):
    """A RESTARTED receiver (fresh instance, durability='full', empty
    _durable_paths) that adopts a previous crash's orphans must fsync them
    at adoption — existence is not durability."""
    store, dst = mk(tmp_path, "src"), mk(tmp_path, "dst")
    payloads = make_payloads(rng)
    build_v1(store, payloads)
    push_delta(store, dst, "app", "v1")
    inject_v2_wide(store, payloads)
    with inject(0, FaultSpec(point="wire.commit", mode="crash",
                             match=dst.root)):
        fan = replicate_fanout(store, [dst], "app", "v2")
    assert not fan.ok and not dst.has_image("app", "v2")

    dst2 = LayerStore(str(tmp_path / "dst"), chunk_bytes=512,
                      durability="full")     # restart analogue
    before = dst2.fsyncs
    stats = push_delta(store, dst2, "app", "v2")
    assert stats.bytes_payload == 0          # pure adoption, no resend
    assert dst2.fsyncs > before              # adoption scheduled the fsync
    assert dst2.verify_image("app", "v2", deep=True) == []
    assert snapshot(dst2, "app", "v2") == snapshot(store, "app", "v2")


# ----------------------------------------------------- harness smoke run
@pytest.mark.parametrize("mode", ["drop", "corrupt", "delay", "crash"])
def test_chaos_cell_relay(tmp_path, mode):
    cell = run_cell("relay", mode, seed=11, base_dir=str(tmp_path))
    assert cell.ok and cell.fired >= 1


@pytest.mark.parametrize("mode",
                         ["drop", "corrupt", "delay", "crash", "bitrot"])
def test_chaos_cell_bundle(tmp_path, mode):
    """The passive-registry cells: faulted publishes leave a stale-but-
    consistent index, faulted/rotten fetches are skipped and replanned
    (or fall back to the smart remote) — every mode converges the
    follower bit-identically to the published head."""
    cell = run_cell("bundle", mode, seed=2, base_dir=str(tmp_path))
    assert cell.ok and cell.fired >= 1


def test_parse_seeds_shard_shorthand():
    """The CI matrix slices one seed range with 'I::S' strides: the 4
    shards must partition [0, SOAK_SEEDS) exactly — no seed lost, none
    soaked twice."""
    from repro.ft.chaos import SOAK_SEEDS, parse_seeds
    assert list(parse_seeds("4")) == [4]                # one seed
    assert list(parse_seeds("2:5")) == [2, 3, 4]
    assert list(parse_seeds("1:9:3")) == [1, 4, 7]
    shards = [list(parse_seeds(f"{i}::4")) for i in range(4)]
    assert shards[1][:2] == [1, 5]
    flat = [s for shard in shards for s in shard]
    assert sorted(flat) == list(range(SOAK_SEEDS))
    assert len(flat) == len(set(flat)) == SOAK_SEEDS


def test_chaos_cell_failure_prints_repro(tmp_path):
    from repro.ft import chaos as chaos_mod

    def broken(base_dir, mode, seed):
        raise AssertionError("deliberately broken cell")

    orig = chaos_mod._RUNNERS["push"]
    chaos_mod._RUNNERS["push"] = broken
    try:
        cells = chaos_mod.run_matrix([3], modes=["drop"],
                                     scenarios=["push"])
    finally:
        chaos_mod._RUNNERS["push"] = orig
    assert len(cells) == 1 and not cells[0].ok
    assert "--seeds 3" in cells[0].error and "push" in cells[0].error
