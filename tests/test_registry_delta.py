"""Delta replication (§III.C redeployment): push_delta/pull_delta must be
bit-identical to the seed full push at the remote, keep the paper's
in-place-mutation rejection, stay crash-atomic, and verify incrementally
(only new layers deeply). Plus the DeltaBundle wire-format round trip and
the checkpoint replicate/follower integration."""
import os

import numpy as np
import pytest

from repro.core import (DeltaBundle, DeltaFormatError, ImageConfig,
                        Instruction, LayerDescriptor, LayerStore, Manifest,
                        PushRejected, TensorRecord, chain_checksum,
                        content_checksum, decode_delta, diff_layer_host,
                        encode_delta, export_delta, import_delta,
                        inject_payload_update, new_uuid, pull_delta, push,
                        push_delta, sha256_hex)


def mk(tmp_path, name="store"):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "src", "content"),
    Instruction("RUN", "build", "content", derives_from=["src"]),
    Instruction("RUN", "deps", "content"),            # independent of src
    Instruction("CMD", "run", "config"),
]


def make_payloads(rng):
    src = {"a.py": rng.standard_normal(1000).astype(np.float32),
           "b.py": rng.standard_normal(500).astype(np.float32)}
    build = {"bin": (src["a.py"] * 2 + 1)}
    deps = {"lib": rng.standard_normal(4000).astype(np.float32)}
    return src, build, deps


def build_v1(store, rng):
    src, build, deps = make_payloads(rng)
    prov = {"src": lambda: src, "build": lambda: build,
            "deps": lambda: deps}
    store.build_image("app", "v1", INS, prov)
    return src, build, deps


def inject_v2(store, src, build, deps):
    src2 = {k: v.copy() for k, v in src.items()}
    src2["b.py"][3] = 42.0                        # 1-chunk edit, a.py same
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"build": lambda: build,
                                     "deps": lambda: deps})
    return src2


def store_snapshot(store, name, tag):
    """Everything that defines an image at a store, as comparable bytes:
    manifest + config JSON, every layer descriptor's on-disk bytes, and
    every referenced blob."""
    manifest, config = store.read_image(name, tag)
    layers = {}
    blobs = {}
    for lid in manifest.layer_ids:
        with open(store._layer_path(lid), "rb") as f:
            layers[lid] = f.read()
        for rec in store.read_layer(lid).records:
            for h in rec.chunks:
                blobs[h] = store.read_blob(h)
    return {"manifest": manifest.to_json(), "config": config.to_json(),
            "layers": layers, "blobs": blobs}


# ----------------------------------------------------------- equivalence
def test_delta_push_bit_identical_to_seed_push(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    inject_v2(store, src, build, deps)

    seed_remote, delta_remote = mk(tmp_path, "rs"), mk(tmp_path, "rd")
    for tag in ("v1", "v2"):
        push(store, seed_remote, "app", tag)
        push_delta(store, delta_remote, "app", tag)
        assert store_snapshot(seed_remote, "app", tag) == \
            store_snapshot(delta_remote, "app", tag)
        # and both match the source exactly
        assert store_snapshot(store, "app", tag) == \
            store_snapshot(delta_remote, "app", tag)
    assert delta_remote.verify_image("app", "v2", deep=True) == []


def test_delta_push_sends_only_the_delta(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")     # bootstrap: full transfer
    inject_v2(store, src, build, deps)
    stats = push_delta(store, remote, "app", "v2")
    # ONE changed 512-byte chunk of b.py is the only payload on the wire
    assert stats.blobs_sent == 1
    assert stats.bytes_payload == 512
    assert stats.bytes_deduped > 0
    assert stats.bytes_sent == stats.bytes_payload + stats.bytes_meta
    # incremental verification: ONLY the injected src layer went deep;
    # everything else rode the re-key table or was already held
    assert stats.layers_deep_verified == 1
    assert stats.layers_rekey_verified >= 1
    assert stats.blobs_hashed_remote == 1
    # ... and an INDEPENDENT full deep verification still passes
    assert remote.verify_image("app", "v2", deep=True) == []


def test_pull_delta_roundtrip(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    src2 = inject_v2(store, src, build, deps)
    local = mk(tmp_path, "local")
    pull_delta(store, local, "app", "v2")
    assert local.verify_image("app", "v2", deep=True) == []
    loaded = local.load_image_payload("app", "v2")
    assert np.array_equal(loaded["b.py"], src2["b.py"])


# ------------------------------------------------------------- rejection
def test_in_place_mutation_rejected_by_delta_push(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    # naive bypass: mutate the layer content under the SAME id
    m, _ = store.read_image("app", "v1")
    layer = store.read_layer(m.layer_ids[1])
    from repro.core import BuildReport, apply_edits
    src2 = {k: v.copy() for k, v in src.items()}
    src2["b.py"][0] = 9.0
    d = diff_layer_host(layer, src2)
    apply_edits(store, layer, d, BuildReport())
    store.write_layer(layer)
    with pytest.raises(PushRejected):
        push_delta(store, remote, "app", "v1")


def test_corrupt_transfer_rejected(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    from repro.core import DeltaReceiver
    receiver = DeltaReceiver(remote)
    with pytest.raises(PushRejected):
        receiver.receive_blob(sha256_hex(b"expected"), b"tampered")


def test_tampered_bundle_rejected(tmp_path, rng):
    store = mk(tmp_path)
    build_v1(store, rng)
    data = bytearray(export_delta(store, "app", "v1"))
    data[-1] ^= 0xFF                       # flip a payload byte
    with pytest.raises(DeltaFormatError):
        decode_delta(bytes(data))


# ----------------------------------------------------------- crash safety
def test_crash_mid_push_leaves_previous_tag_intact(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    inject_v2(store, src, build, deps)

    class Boom(RuntimeError):
        pass

    # die AFTER the changed blob landed but before any descriptor/commit:
    # the remote is left with an orphan blob and no new manifest
    def dying_write_layer(layer, encoded=None):
        raise Boom()

    remote.write_layer = dying_write_layer      # instance shadow
    try:
        with pytest.raises(Boom):
            push_delta(store, remote, "app", "v2")
    finally:
        del remote.write_layer                  # restore class method
    # previous tag untouched and fully valid; v2 never became visible
    assert remote.list_tags("app") == ["v1"]
    assert not remote.has_image("app", "v2")
    assert remote.verify_image("app", "v1", deep=True) == []
    # the retry completes cleanly on the same remote
    stats = push_delta(store, remote, "app", "v2")
    assert remote.verify_image("app", "v2", deep=True) == []
    assert stats.layers_deep_verified == 1


def test_crash_at_commit_orphans_reverified_on_retry(tmp_path, rng):
    """A crash AFTER blobs+descriptors landed but before the manifest
    rename leaves orphans at the remote. The retry must not trust them as
    'held' (they were never verified by a committed push) — they are
    re-verified, and the push converges."""
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    inject_v2(store, src, build, deps)

    class Boom(RuntimeError):
        pass

    def dying_write_image(manifest, config):
        raise Boom()

    remote.write_image = dying_write_image
    try:
        with pytest.raises(Boom):
            push_delta(store, remote, "app", "v2")
    finally:
        del remote.write_image
    assert remote.list_tags("app") == ["v1"]     # nothing committed
    stats = push_delta(store, remote, "app", "v2")
    # orphan descriptors were treated as missing, re-sent and re-verified
    assert stats.layers_sent >= 1
    assert stats.layers_deep_verified >= 1
    assert remote.verify_image("app", "v2", deep=True) == []


def test_torn_orphan_blob_replaced_on_retry(tmp_path, rng):
    """A torn blob (exists on disk, bytes don't match its address — the
    un-fsynced leftover of a crashed batch-mode push) must be detected at
    the blob probe, deleted and re-sent, not trusted by existence."""
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    src2 = inject_v2(store, src, build, deps)
    # the genuinely NEW chunk: referenced by v2, not by committed v1
    m1, _ = store.read_image("app", "v1")
    v1_chunks = {h for lid in m1.layer_ids
                 for rec in store.read_layer(lid).records
                 for h in rec.chunks}
    _, cfg = store.read_image("app", "v2")
    h = next(c for c in cfg.history[-1]["delta"]["chunks"]
             if c not in v1_chunks)
    path = remote._blob_path(h)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "wb") as f:
        f.write(b"torn leftover")
    stats = push_delta(store, remote, "app", "v2")
    assert stats.blobs_sent == 1                 # resent despite existing
    assert remote.verify_image("app", "v2", deep=True) == []
    loaded = remote.load_image_payload("app", "v2")
    assert np.array_equal(loaded["b.py"], src2["b.py"])


def _mutate_in_place_consistent(store, rng):
    """A 'naive bypass' source: edit the src layer's content under the SAME
    layer ids and re-key checksums/chains so the image is self-consistent —
    the strongest in-place mutation a malicious pusher could craft."""
    from repro.core import (BuildReport, ImageConfig, apply_edits,
                            chain_checksum, new_uuid)
    m, cfg = store.read_image("app", "v1")
    layers = [store.read_layer(lid, use_cache=False) for lid in m.layer_ids]
    target = layers[1]
    payload = store.load_layer_payload(target)
    payload["b.py"] = payload["b.py"].copy()
    payload["b.py"][0] = -123.0
    d = diff_layer_host(target, payload)
    apply_edits(store, target, d, BuildReport())
    parent = None
    checksums, chains = {}, {}
    for layer in layers:
        layer.chain = chain_checksum(parent, layer.checksum,
                                     layer.instruction.text)
        store.write_layer(layer)
        checksums[layer.layer_id] = layer.checksum
        chains[layer.layer_id] = layer.chain
        parent = layer.chain
    new_cfg = ImageConfig(config_id=new_uuid(), arch=cfg.arch,
                          version=cfg.version + 1,
                          layer_checksums=checksums, layer_chains=chains,
                          history=cfg.history)
    m.config_id = new_cfg.config_id
    store.write_image(m, new_cfg)


def test_import_delta_rejects_in_place_mutation(tmp_path, rng):
    """The offline path must enforce the same immutability gate as the
    live push: a committed layer id arriving with a diverged checksum is
    rejected, even inside a fully self-consistent bundle."""
    store = mk(tmp_path)
    build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    before = store_snapshot(remote, "app", "v1")
    _mutate_in_place_consistent(store, rng)
    data = export_delta(store, "app", "v1")
    with pytest.raises(PushRejected):
        import_delta(remote, data)
    # the remote's committed image is untouched, bit for bit
    assert store_snapshot(remote, "app", "v1") == before


def test_mutation_gate_survives_deep_tag_history(tmp_path, rng):
    """A layer referenced only by a tag OLDER than the negotiate scan
    window must still be protected: the committed-layer set covers every
    tag, only the re-key index is windowed."""
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    cur, tag = src, "v1"
    for i in range(10):               # 10 newer tags ('w..' sort after v1)
        cur = {k: v.copy() for k, v in cur.items()}
        cur["b.py"][1] = float(i + 5)
        new_tag = f"w{i:02d}"
        inject_payload_update(store, "app", tag, new_tag, {"src": cur},
                              providers={"build": lambda: build,
                                         "deps": lambda: deps})
        push_delta(store, remote, "app", new_tag)
        tag = new_tag
    # v1's src layer id is now referenced ONLY by the oldest remote tag,
    # outside DeltaReceiver.TAG_WINDOW. An in-place mutation of it must
    # still be rejected — and its descriptor never overwritten.
    from repro.core import DeltaReceiver
    assert len(remote.list_tags("app")) > DeltaReceiver.TAG_WINDOW
    before = store_snapshot(remote, "app", "v1")
    _mutate_in_place_consistent(store, rng)
    with pytest.raises(PushRejected):
        push_delta(store, remote, "app", "v1")
    assert store_snapshot(remote, "app", "v1") == before


# -------------------------------------------------- offline bundle format
def test_export_import_delta_offline(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    src2 = inject_v2(store, src, build, deps)
    remote = mk(tmp_path, "remote")
    push_delta(store, remote, "app", "v1")
    data = export_delta(store, "app", "v2", base_tag="v1")
    stats = import_delta(remote, data)
    assert stats.blobs_sent >= 1
    assert remote.verify_image("app", "v2", deep=True) == []
    loaded = remote.load_image_payload("app", "v2")
    assert np.array_equal(loaded["b.py"], src2["b.py"])
    # the offline delta must be FAR smaller than the full image
    full = export_delta(store, "app", "v2")
    assert len(data) < len(full) / 2


def test_injection_history_records_delta(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    inject_v2(store, src, build, deps)
    _, config = store.read_image("app", "v2")
    delta = config.history[-1]["delta"]
    assert delta["base"] == ["app", "v1"]
    assert len(delta["injected"]) == 1     # src layer
    assert len(delta["rekeyed"]) >= 1      # deps / CMD downstream
    assert delta["n_chunks"] >= 1
    assert 1 <= len(delta["chunks"]) <= delta["n_chunks"]
    for h in delta["chunks"]:
        assert store.has_blob(h)


# -------------------------------------------- hypothesis: wire round trip
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _name = st.text(st.characters(min_codepoint=48, max_codepoint=122),
                    min_size=1, max_size=12)

    @st.composite
    def bundles(draw):
        n_blobs = draw(st.integers(0, 5))
        blobs = {}
        for _ in range(n_blobs):
            payload = draw(st.binary(min_size=0, max_size=300))
            blobs[sha256_hex(payload)] = payload
        n_layers = draw(st.integers(0, 3))
        layers = []
        parent = None
        for i in range(n_layers):
            recs = []
            for j in range(draw(st.integers(0, 2))):
                chunk_ids = draw(st.lists(
                    st.sampled_from(sorted(blobs) or [sha256_hex(b"x")]),
                    min_size=1, max_size=3)) if blobs else []
                recs.append(TensorRecord(
                    name=f"t{j}", shape=(4,), dtype="float32",
                    chunk_bytes=512, chunks=tuple(chunk_ids)))
            ins = Instruction("COPY", draw(_name), "content")
            checksum = content_checksum(recs)
            layer = LayerDescriptor(
                layer_id=new_uuid(), version=draw(st.integers(1, 9)),
                instruction=ins, checksum=checksum,
                chain=chain_checksum(parent, checksum, ins.text),
                records=recs, empty=not recs)
            parent = layer.chain
            layers.append(layer)
        manifest = Manifest(name=draw(_name), tag=draw(_name),
                            layer_ids=[la.layer_id for la in layers],
                            config_id=new_uuid())
        config = ImageConfig(
            config_id=manifest.config_id, arch="generic",
            version=draw(st.integers(1, 5)),
            layer_checksums={la.layer_id: la.checksum for la in layers},
            layer_chains={la.layer_id: la.chain for la in layers},
            history=[{"instruction": "INJECT", "edits": 1}])
        rekey = {la.layer_id: new_uuid()
                 for la in layers if draw(st.booleans())}
        return DeltaBundle(name=manifest.name, tag=manifest.tag,
                           base_tag=draw(_name), manifest=manifest,
                           config=config, layers=layers, rekey=rekey,
                           blobs=blobs)

    from conftest import max_examples

    @settings(max_examples=max_examples(30), deadline=None)
    @given(bundles())
    def test_delta_bundle_roundtrip(bundle):
        back = decode_delta(encode_delta(bundle))
        assert back.name == bundle.name
        assert back.tag == bundle.tag
        assert back.base_tag == bundle.base_tag
        assert back.manifest.to_json() == bundle.manifest.to_json()
        assert back.config.to_json() == bundle.config.to_json()
        assert [la.to_json() for la in back.layers] == \
            [la.to_json() for la in bundle.layers]
        assert back.rekey == bundle.rekey
        assert back.blobs == bundle.blobs
        # deterministic: encode(decode(encode(x))) == encode(x)
        assert encode_delta(back) == encode_delta(bundle)


# -------------------------------------------------- ckpt replicate + serve
def test_checkpoint_replicate_ships_delta(tmp_path):
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    params = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64)}
    opt = {"m": np.zeros((64, 64), np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    remote = LayerStore(str(tmp_path / "serve"), chunk_bytes=512)
    s0 = mgr.replicate(remote)
    assert remote.verify_image("ckpt", mgr.tag_of(0), deep=True) == []

    params2 = {"w": params["w"].copy()}
    params2["w"][0, 0] += 1.0                       # one-chunk change
    mgr.save(1, params2, opt)
    s1 = mgr.replicate(remote)
    # the second replication is O(changed bytes), not O(checkpoint)
    assert s1.bytes_payload < s0.bytes_payload / 4
    assert remote.verify_image("ckpt", mgr.tag_of(1), deep=True) == []


def test_checkpoint_follower_pulls_delta(tmp_path):
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    from repro.serve import CheckpointFollower
    params = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64)}
    opt = {"m": np.zeros((64, 64), np.float32)}
    mgr = CheckpointManager(str(tmp_path / "train"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512))
    mgr.save(0, params, opt)
    fol = CheckpointFollower(mgr.store, str(tmp_path / "serve"))
    got = fol.poll()
    assert got is not None
    step, p, o = got
    assert step == 0
    assert np.array_equal(np.asarray(p["w"]), params["w"])
    assert fol.poll() is None                       # already up to date

    params2 = {"w": params["w"].copy()}
    params2["w"][0, 0] += 1.0                       # one-chunk change
    mgr.save(3, params2, opt)
    step, p, _ = fol.poll()
    assert step == 3
    assert np.array_equal(np.asarray(p["w"]), np.asarray(params2["w"]))
    # the pull was a delta: payload well under the full checkpoint size
    assert fol.last_pull.bytes_payload < params["w"].nbytes / 4
    assert fol.local.verify_image("ckpt", f"step-{3:08d}", deep=True) == []


def test_push_stats_account_meta_and_wall(tmp_path, rng):
    """Satellite: seed push's bytes_sent must now include descriptor +
    manifest/config bytes, and report dedup savings + wall time."""
    store = mk(tmp_path)
    build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    stats = push(store, remote, "app", "v1")
    manifest, config = store.read_image("app", "v1")
    from repro.core.manifest import dumps
    meta_floor = len(dumps(manifest.to_json()).encode()) + \
        len(dumps(config.to_json()).encode())
    assert stats.bytes_meta > meta_floor          # descriptors counted too
    assert stats.bytes_sent == stats.bytes_payload + stats.bytes_meta
    assert stats.wall_s > 0
    # second push of the identical tag: all payload deduped
    stats2 = push(store, remote, "app", "v1")
    assert stats2.bytes_payload == 0
    assert stats2.bytes_deduped > 0
