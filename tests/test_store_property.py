"""Property-based tests (hypothesis) for the store's core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import max_examples

from repro.core import (Instruction, LayerStore, inject_payload_update,
                        new_uuid)

INS = [Instruction("FROM", "base", "config"),
       Instruction("COPY", "data", "content"),
       Instruction("ENV", "x", "config")]


@st.composite
def payload_and_edits(draw):
    n_tensors = draw(st.integers(1, 4))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    payload = {}
    for i in range(n_tensors):
        n = draw(st.integers(1, 3000))
        payload[f"t{i}"] = rng.standard_normal(n).astype(np.float32)
    n_edits = draw(st.integers(0, 6))
    edits = []
    for _ in range(n_edits):
        t = draw(st.integers(0, n_tensors - 1))
        name = f"t{t}"
        idx = draw(st.integers(0, payload[name].size - 1))
        val = draw(st.floats(-1e6, 1e6, allow_nan=False))
        edits.append((name, idx, np.float32(val)))
    return payload, edits


@settings(max_examples=max_examples(25), deadline=None)
@given(payload_and_edits())
def test_injection_equivalence_and_isolation(tmp_path_factory, pe):
    payload, edits = pe
    tmp = tmp_path_factory.mktemp(new_uuid()[:8])
    store = LayerStore(str(tmp), chunk_bytes=256)
    store.build_image("m", "v1", INS, {"data": lambda: payload})

    new_payload = {k: v.copy() for k, v in payload.items()}
    for name, idx, val in edits:
        new_payload[name][idx] = val

    inject_payload_update(store, "m", "v1", "v2", {"data": new_payload})

    # INVARIANT 1: injected image verifies (key+lock consistent)
    assert store.verify_image("m", "v2") == []
    # INVARIANT 2: loads bit-exact as the new payload
    loaded = store.load_image_payload("m", "v2")
    for k in payload:
        assert np.array_equal(loaded[k], new_payload[k]), k
    # INVARIANT 3: the old image is untouched and still verifies
    assert store.verify_image("m", "v1") == []
    old = store.load_image_payload("m", "v1")
    for k in payload:
        assert np.array_equal(old[k], payload[k]), k
    # INVARIANT 4: injection == rebuild (content addressing agrees)
    store2 = LayerStore(str(tmp) + "_rb", chunk_bytes=256)
    m2, c2, _ = store2.build_image("m", "vr", INS,
                                   {"data": lambda: new_payload})
    m1, c1 = store.read_image("m", "v2")
    l_inj = store.read_layer(m1.layer_ids[1])
    l_rb = store2.read_layer(m2.layer_ids[1])
    assert l_inj.checksum == l_rb.checksum     # same content => same checksum


@settings(max_examples=max_examples(15), deadline=None)
@given(st.integers(1, 5000), st.integers(0, 2**31))
def test_chunking_roundtrip(n, seed):
    from repro.core import bytes_to_tensor, chunk_tensor
    rng = np.random.default_rng(seed)
    arr = rng.standard_normal(n).astype(np.float32)
    rec, pairs = chunk_tensor("x", arr, 512)
    data = b"".join(p for _, p in pairs)
    back = bytes_to_tensor(data, rec.shape, rec.dtype)
    assert np.array_equal(back, arr)
    # chunk hashes deterministic
    rec2, pairs2 = chunk_tensor("x", arr, 512)
    assert rec.chunks == rec2.chunks
