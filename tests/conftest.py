import os

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def max_examples(default: int) -> int:
    """Hypothesis example count: the PR path runs the per-suite default;
    the nightly CI job raises it via HYPOTHESIS_MAX_EXAMPLES (see
    .github/workflows/ci.yml) to hunt rare generative counterexamples."""
    return int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", default))
