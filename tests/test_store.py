"""Core layer store: build, cache, fall-through, load, decompose, verify."""
import numpy as np

from repro.core import Instruction, LayerStore


def mk_store(tmp_path, chunk=1024):
    return LayerStore(str(tmp_path / "store"), chunk_bytes=chunk)


def payloads(rng, scale=1.0):
    return {
        "params": {"w0": (rng.standard_normal((64, 64)) * scale)
                   .astype(np.float32),
                   "w1": rng.standard_normal((128, 32)).astype(np.float32)},
        "opt_init": {"m": np.zeros((64, 64), np.float32)},
    }


INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "params", "content"),
    Instruction("RUN", "opt_init", "content"),
    Instruction("CMD", "serve", "config"),
]


def providers(p):
    return {k: (lambda v=v: v) for k, v in p.items()}


def test_build_load_roundtrip(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    loaded = store.load_image_payload("m", "v1")
    for k in ("w0", "w1"):
        assert np.array_equal(loaded[k], p["params"][k])
    assert store.verify_image("m", "v1") == []


def test_cache_hit_on_unchanged_rebuild(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    _, _, rep = store.build_image("m", "v2", INS, providers(p),
                                  parent=("m", "v1"))
    # all four layers cached; the COPY content compare (DLC rule 3) is
    # answered by the fingerprint prefilter — no chunk re-hash at all
    assert rep.layers_cached == 4
    assert rep.layers_built == 0
    assert rep.chunks_prefiltered > 0
    assert rep.bytes_hashed == 0
    assert rep.derivations_run == 0


def test_cache_hit_without_fingerprints_rehashes(tmp_path, rng):
    """record_fingerprints=False keeps the seed (Docker-faithful) DLC rule
    3: a COPY cache hit costs a full serialize+hash of the payload."""
    store = LayerStore(str(tmp_path / "store_nofp"), chunk_bytes=1024,
                       record_fingerprints=False)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    _, _, rep = store.build_image("m", "v2", INS, providers(p),
                                  parent=("m", "v1"))
    assert rep.layers_cached == 4
    assert rep.bytes_hashed > 0          # content compare isn't free
    assert rep.chunks_prefiltered == 0


def test_fall_through_rebuilds_downstream(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    p2 = payloads(rng)
    p2["params"]["w0"][0, 0] += 1.0
    p2["opt_init"] = p["opt_init"]       # unchanged payload...
    _, _, rep = store.build_image("m", "v2", INS, providers(p2),
                                  parent=("m", "v1"))
    # ...but Docker falls through: the RUN layer is re-executed anyway
    assert rep.derivations_run == 1
    assert rep.layers_built >= 3         # params + opt + CMD
    assert store.verify_image("m", "v2") == []


def test_instruction_change_invalidates(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    ins2 = list(INS)
    ins2[2] = Instruction("RUN", "opt_init", "content")
    ins2[3] = Instruction("CMD", "serve --port 8080", "config")
    _, _, rep = store.build_image("m", "v2", ins2, providers(p),
                                  parent=("m", "v1"))
    assert rep.layers_cached == 3        # FROM, COPY, RUN
    assert rep.layers_built == 1         # CMD literal changed (rule 4)


def test_export_import_explicit_decompose(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    bundle = store.export_image("m", "v1")
    store2 = mk_store(tmp_path / "other")
    name, tag = store2.import_image(bundle)
    assert (name, tag) == ("m", "v1")
    assert store2.verify_image("m", "v1") == []
    loaded = store2.load_image_payload("m", "v1")
    assert np.array_equal(loaded["w0"], p["params"]["w0"])


def test_verify_detects_blob_corruption(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    m, _, _ = store.build_image("m", "v1", INS, providers(p))
    layer = store.read_layer(m.layer_ids[1])
    h = layer.records[0].chunks[0]
    with open(store._blob_path(h), "wb") as f:
        f.write(b"corrupted")
    problems = store.verify_image("m", "v1")
    assert any("corrupt" in p_ for p_ in problems)


def test_chunk_dedup_across_images(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("a", "v1", INS, providers(p))
    before = sum(1 for _ in _blobs(store))
    store.build_image("b", "v1", INS, providers(p))   # same content
    after = sum(1 for _ in _blobs(store))
    assert before == after               # zero new blobs


def _blobs(store):
    import os
    root = os.path.join(store.root, "blobs")
    for dirpath, _, files in os.walk(root):
        yield from files


# ----------------------------------------------------- GC + tag caching
def test_gc_sweeps_unreferenced_blobs_and_layers(tmp_path, rng):
    store = mk_store(tmp_path)
    p = payloads(rng)
    m1, _, _ = store.build_image("m", "v1", INS, providers(p))
    p2 = payloads(rng, scale=2.0)                    # all-new content
    store.build_image("m", "v2", INS, providers(p2))
    blobs_before = sum(1 for _ in _blobs(store))
    # drop v1: its exclusive blobs + layers become unreferenced
    assert store.remove_image("m", "v1")
    stats = store.gc()
    assert stats["blobs_swept"] > 0
    assert stats["layers_swept"] > 0
    assert stats["bytes_swept"] > 0
    assert sum(1 for _ in _blobs(store)) < blobs_before
    # the surviving image is untouched and fully valid
    assert store.verify_image("m", "v2", deep=True) == []
    # idempotent: nothing left to sweep
    assert store.gc()["blobs_swept"] == 0


def test_gc_protects_open_batch_transaction(tmp_path, rng):
    store = LayerStore(str(tmp_path / "b"), chunk_bytes=1024,
                       durability="batch")
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    # an in-flight batch write: blob exists on disk but is NOT yet
    # referenced by any manifest (its commit hasn't happened)
    from repro.core import sha256_hex
    data = b"pending-chunk" * 50
    h = sha256_hex(data)
    store.write_blob(h, data)
    stats = store.gc()
    assert store.has_blob(h), "gc must not sweep an open transaction's blob"
    # after the transaction commits (a no-op image refresh flushes dirty
    # state), the blob is still unreferenced -> NOW sweepable
    m, c = store.read_image("m", "v1")
    store.write_image(m, c)
    store.gc()
    assert not store.has_blob(h)
    assert stats is not None


def test_list_tags_cached_and_invalidated(tmp_path, rng):
    import os
    store = mk_store(tmp_path)
    p = payloads(rng)
    store.build_image("m", "v1", INS, providers(p))
    assert store.list_tags("m") == ["v1"]
    calls = {"n": 0}
    orig = os.listdir

    def counting(path):
        calls["n"] += 1
        return orig(path)

    os.listdir = counting
    try:
        assert store.list_tags("m") == ["v1"]        # served from cache
        assert calls["n"] == 0
    finally:
        os.listdir = orig
    store.build_image("m", "v2", INS, providers(p))  # commit invalidates
    assert store.list_tags("m") == ["v1", "v2"]
    store.remove_image("m", "v1")                    # removal invalidates
    assert store.list_tags("m") == ["v2"]


def test_ckpt_gc_bounds_disk_growth(tmp_path):
    """The old manifest-unlink GC stranded every superseded blob forever;
    mark-and-sweep must keep the blob count bounded by `keep` images."""
    from repro.ckpt import CheckpointManager, CheckpointPolicy
    params = {"w": np.arange(8192, dtype=np.float32)}
    opt = {"m": np.zeros(8192, np.float32)}
    mgr = CheckpointManager(str(tmp_path / "ck"), "t",
                            CheckpointPolicy(async_write=False,
                                             chunk_bytes=512, keep=2))
    counts = []
    p = params
    for step in range(8):
        p = {"w": p["w"].copy()}
        p["w"][step * 128] += 1.0                    # one chunk per save
        mgr.save(step, p, opt)
        counts.append(sum(1 for _ in _blobs(mgr.store)))
    # once retention kicks in, blob count stays flat (each save adds ~2
    # chunks and the sweep removes the superseded ones)
    assert counts[-1] <= counts[2] + 4
    assert mgr.restore()[2] == 7
    assert mgr.store.verify_image("ckpt", mgr.tag_of(7), deep=True) == []
