"""Pallas kernels vs pure-jnp/numpy oracles — shape/dtype sweeps in
interpret mode (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fingerprint_chunks_ref
from repro.kernels.fingerprint.ops import fingerprint
from repro.kernels.flash_attention.ops import flash_attention, reference
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_reference


FA_CASES = [
    # B, Hq, KVH, S, D, window, qb, kb
    (2, 4, 2, 128, 64, None, 64, 64),
    (1, 4, 4, 256, 32, None, 128, 64),
    (2, 8, 2, 128, 64, 32, 32, 32),
    (1, 2, 1, 64, 128, None, 64, 64),
]


@pytest.mark.parametrize("B,Hq,KVH,S,D,win,qb,kb", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(B, Hq, KVH, S, D, win, qb, kb, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KVH, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KVH, S, D), dtype)
    out = flash_attention(q, k, v, window=win, q_block=qb, kv_block=kb,
                          interpret=True)
    ref = reference(q, k, v, window=win)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    assert np.abs(np.asarray(out, np.float32) -
                  np.asarray(ref, np.float32)).max() < tol


SSD_CASES = [
    (2, 64, 3, 8, 1, 16, 16),
    (1, 128, 4, 16, 2, 8, 32),
    (2, 64, 4, 8, 4, 16, 64),
]


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel(B, S, H, P, G, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bc = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    Cc = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    D = jax.random.normal(ks[5], (H,)) * 0.1
    y, h = ssd(x, dt, A, Bc, Cc, D, chunk=chunk, interpret=True)
    y_ref, h_ref = ssd_reference(x, dt, A, Bc, Cc, D)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    assert np.abs(np.asarray(y, np.float32) -
                  np.asarray(y_ref, np.float32)).max() < tol
    assert np.abs(np.asarray(h - h_ref)).max() < tol


@pytest.mark.parametrize("dtype,n,chunk", [
    ("float32", 5000, 1024), ("int8", 10000, 512), ("float32", 100, 1024),
    ("int32", 3000, 256),
])
def test_fingerprint_kernel_bit_exact(dtype, n, chunk):
    rng = np.random.default_rng(0)
    if dtype in ("int8", "int32"):
        x = rng.integers(-100, 100, n).astype(dtype)
    else:
        x = rng.standard_normal(n).astype(dtype)
    got = np.asarray(fingerprint(jnp.asarray(x), chunk, interpret=True))
    ref = fingerprint_chunks_ref(x, chunk)
    assert np.array_equal(got, ref)


def test_fingerprint_kernel_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(4096), jnp.bfloat16)
    got = np.asarray(fingerprint(x, 1024, interpret=True))
    ref = fingerprint_chunks_ref(np.asarray(x), 1024)
    assert np.array_equal(got, ref)


def test_fingerprint_kernel_tiled_large_chunk():
    """Chunks wider than one inner tile: cross-tile xor/add accumulation
    must still be bit-identical to the single-pass oracle."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal(40000).astype(np.float32)
    from repro.kernels.fingerprint.ops import fingerprint as fp_op
    got = np.asarray(fp_op(jnp.asarray(x), 1 << 16, tile_lanes=1024,
                           interpret=True))
    ref = fingerprint_chunks_ref(x, 1 << 16)
    assert np.array_equal(got, ref)


def test_fingerprint_kernel_packed_tree():
    """Mixed-dtype tree through the Pallas kernel in ONE dispatch: per-row
    width masking must reproduce every leaf's per-leaf fingerprint."""
    import ml_dtypes
    from repro.kernels.fingerprint.ops import fingerprint_tree as fp_tree
    rng = np.random.default_rng(4)
    tree = {
        "f32": rng.standard_normal(3000).astype(np.float32),
        "i8": rng.integers(-100, 100, 2000).astype(np.int8),
        "bf16": rng.standard_normal(1025).astype(ml_dtypes.bfloat16),
        "bool": rng.standard_normal(300) > 0,
    }
    got = fp_tree(tree, 1024, interpret=True)
    for name, v in tree.items():
        assert np.array_equal(got[name],
                              fingerprint_chunks_ref(np.asarray(v), 1024)), \
            name


def test_fingerprint_kernel_sensitivity():
    rng = np.random.default_rng(2)
    x = rng.standard_normal(8192).astype(np.float32)
    y = x.copy()
    y[5000] += 1e-7
    fx = np.asarray(fingerprint(jnp.asarray(x), 1024, interpret=True))
    fy = np.asarray(fingerprint(jnp.asarray(y), 1024, interpret=True))
    changed = np.nonzero(np.any(fx != fy, axis=-1))[0]
    assert list(changed) == [5000 * 4 // 1024]
