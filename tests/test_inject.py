"""The paper's injection method (C1-C4): equivalence, integrity, registry."""
import numpy as np
import pytest

from repro.core import (Instruction, LayerStore, PushRejected,
                        StructureChangeError, diff_layer_host,
                        inject_payload_update, push)


def mk(tmp_path, name="store"):
    return LayerStore(str(tmp_path / name), chunk_bytes=512)


INS = [
    Instruction("FROM", "base", "config"),
    Instruction("COPY", "src", "content"),
    Instruction("RUN", "build", "content", derives_from=["src"]),
    Instruction("RUN", "deps", "content"),            # independent of src
    Instruction("CMD", "run", "config"),
]


def make_payloads(rng):
    src = {"a.py": rng.standard_normal(1000).astype(np.float32),
           "b.py": rng.standard_normal(500).astype(np.float32)}
    build = {"bin": (src["a.py"] * 2 + 1)}            # derived from src
    deps = {"lib": rng.standard_normal(4000).astype(np.float32)}
    return src, build, deps


def build_v1(store, rng):
    src, build, deps = make_payloads(rng)
    prov = {"src": lambda: src, "build": lambda: build,
            "deps": lambda: deps}
    store.build_image("app", "v1", INS, prov)
    return src, build, deps


def test_injection_equals_rebuild(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    src2 = {k: v.copy() for k, v in src.items()}
    src2["b.py"][3] = 42.0                           # 1-chunk "interpreted" edit
    build2 = {"bin": src2["a.py"] * 2 + 1}           # unchanged (a.py same)
    m, c, rep = inject_payload_update(
        store, "app", "v1", "v2", {"src": src2},
        providers={"build": lambda: build2, "deps": lambda: deps})
    assert store.verify_image("app", "v2") == []
    loaded = store.load_image_payload("app", "v2")
    assert np.array_equal(loaded["b.py"], src2["b.py"])
    assert np.array_equal(loaded["lib"], deps["lib"])
    # O(delta): exactly one chunk rewritten, deps layer NOT re-derived
    assert rep.chunks_written == 1
    assert rep.derivations_run == 1      # only `build` (derives_from=src)
    assert rep.layers_rekeyed >= 1       # deps re-keyed, not rebuilt


def test_clone_before_inject_preserves_old_image(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    before = store.load_image_payload("app", "v1")
    src2 = {k: v.copy() for k, v in src.items()}
    src2["a.py"][0] = -1.0
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"build": lambda: {"bin": src2["a.py"] * 2 + 1}})
    after = store.load_image_payload("app", "v1")
    for k in before:
        assert np.array_equal(before[k], after[k]), k   # C4: untouched
    assert store.verify_image("app", "v1") == []
    # layer ids diverged (new identity for the patched layer)
    m1, _ = store.read_image("app", "v1")
    m2, _ = store.read_image("app", "v2")
    assert m1.layer_ids[1] != m2.layer_ids[1]
    assert m1.layer_ids[0] == m2.layer_ids[0]           # FROM layer shared


def test_structure_change_rejected(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    src2 = dict(src)
    src2["c.py"] = np.ones(10, np.float32)              # new file => compiled
    with pytest.raises(StructureChangeError):
        inject_payload_update(store, "app", "v1", "v2", {"src": src2})


def test_registry_accepts_injected_rejects_mutated(tmp_path, rng):
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    remote = mk(tmp_path, "remote")
    push(store, remote, "app", "v1")
    # injected image pushes cleanly (new layer id)
    src2 = {k: v.copy() for k, v in src.items()}
    src2["b.py"][0] = 9.0
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"build": lambda: build})
    stats = push(store, remote, "app", "v2")
    assert stats.layers_dedup >= 1       # shared layers not resent
    # in-place mutation WITHOUT new id (naive bypass) must be rejected
    m, _ = store.read_image("app", "v1")
    layer = store.read_layer(m.layer_ids[1])
    from repro.core.inject import apply_edits
    from repro.core.store import BuildReport
    d = diff_layer_host(layer, {**src, "b.py": src2["b.py"]})
    apply_edits(store, layer, d, BuildReport())         # same id, new content
    store.write_layer(layer)
    with pytest.raises(PushRejected):
        push(store, remote, "app", "v1")


def test_config_change_goes_through_normal_path(tmp_path, rng):
    """Paper: config layers are empty — let Docker handle them."""
    store = mk(tmp_path)
    src, build, deps = build_v1(store, rng)
    ins2 = list(INS)
    ins2[4] = Instruction("CMD", "run --fast", "config")
    prov = {"src": lambda: src, "build": lambda: build,
            "deps": lambda: deps}
    _, _, rep = store.build_image("app", "v2", ins2, prov,
                                  parent=("app", "v1"))
    assert rep.layers_built == 1         # just the empty CMD layer
    assert rep.bytes_serialized == 0 or rep.bytes_serialized < 100
