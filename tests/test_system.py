"""End-to-end system tests: train + incremental checkpointing + restart
resume + serving — the paper's technique embedded in a real training loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, apply_update, init_opt_state
from repro.serve import Engine


def make_step(cfg, acfg):
    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt, stats = apply_update(acfg, params, opt, grads)
        return params, opt, loss
    return step


def train(cfg, steps, mgr=None, start_step=0, params=None, opt=None,
          save_every=5):
    ds = SyntheticTokens(cfg.vocab, batch=4, seq=32, seed=7)
    acfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=100,
                       weight_decay=0.0)
    step_fn = make_step(cfg, acfg)
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
    losses = []
    for s in range(start_step, steps):
        b = ds.batch_at(s)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step_fn(params, opt, batch)
        losses.append(float(loss))
        if mgr is not None and (s + 1) % save_every == 0:
            mgr.save(s + 1, params, opt)
    if mgr is not None:
        mgr.wait()
    return params, opt, losses


def test_train_ckpt_restart_resumes_bitwise(tmp_path):
    cfg = get_smoke_config("gemma-2b").replace(n_layers=2)
    pol = CheckpointPolicy(incremental=True, async_write=False,
                           chunk_bytes=512)
    # run A: 10 steps straight
    pa, oa, la = train(cfg, 10)
    # run B: 5 steps, "crash", restore, 5 more
    mgr = CheckpointManager(str(tmp_path), cfg.name, pol)
    train(cfg, 5, mgr, save_every=5)
    out = mgr.restore()
    assert out is not None
    params_r, opt_r, step_r = out
    assert step_r == 5
    params_r = jax.tree.map(jnp.asarray, params_r)
    opt_r = jax.tree.map(jnp.asarray, opt_r)
    pb, ob, lb = train(cfg, 10, start_step=5, params=params_r, opt=opt_r)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_ckpt_cost_tracks_change_size(tmp_path):
    """Adapter-style update (one tensor touched) must checkpoint ~that much."""
    cfg = get_smoke_config("yi-6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = {"step": jnp.int32(0)}
    pol = CheckpointPolicy(incremental=True, async_write=False,
                           chunk_bytes=512)
    mgr = CheckpointManager(str(tmp_path), cfg.name, pol)
    mgr.save(0, params, opt)
    params2 = jax.tree.map(lambda a: a, params)
    params2["blocks"] = dict(params["blocks"])
    params2["blocks"]["wq"] = params["blocks"]["wq"] + \
        jnp.ones_like(params["blocks"]["wq"]) * 1e-2
    rep = mgr.save(1, params2, opt)
    total_bytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(params))
    wq_bytes = np.asarray(params["blocks"]["wq"]).nbytes
    assert rep.bytes_serialized <= wq_bytes + 2 * pol.chunk_bytes
    assert rep.bytes_serialized < total_bytes / 10


def test_serving_engine_generates():
    cfg = get_smoke_config("mixtral-8x7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=64)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab))
    out = eng.generate(prompts, steps=8)
    assert out.tokens.shape == (2, 8)
    assert (out.tokens >= 0).all() and (out.tokens < cfg.vocab).all()
    out2 = eng.generate(prompts, steps=8)      # greedy => deterministic
    np.testing.assert_array_equal(out.tokens, out2.tokens)


def test_engine_matches_teacher_forcing():
    """Prefill+decode through the Engine == direct decode loop."""
    cfg = get_smoke_config("yi-6b")
    params = init_params(cfg, jax.random.PRNGKey(3))
    from repro.models import decode_step, init_cache
    B, S = 2, 12
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab))
    eng = Engine(cfg, params, max_len=32)
    res = eng.generate(prompts, steps=4)
    # manual: feed prompts token by token, then greedy decode 4
    cache = init_cache(cfg, B, 32)
    dec = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for t in range(S):
        cache, logits = dec(params, cache, jnp.asarray(prompts[:, t]),
                            jnp.int32(t))
    toks = []
    tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
    for i in range(4):
        toks.append(np.asarray(tok))
        cache, logits = dec(params, cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
    manual = np.stack(toks, 1)
    np.testing.assert_array_equal(res.tokens, manual)


def test_multitenant_dedup_storage(tmp_path):
    """Two fine-tunes sharing a base dedup their common layers (paper §I)."""
    cfg = get_smoke_config("gemma-2b")
    base = init_params(cfg, jax.random.PRNGKey(0))
    pol = CheckpointPolicy(incremental=True, async_write=False,
                           chunk_bytes=512)
    mgr = CheckpointManager(str(tmp_path), cfg.name, pol)
    mgr.save(0, base, {"step": jnp.int32(0)})

    def store_bytes():
        import os
        total = 0
        for dp, _, fs in os.walk(os.path.join(mgr.store.root, "blobs")):
            for f in fs:
                total += os.path.getsize(os.path.join(dp, f))
        return total

    b0 = store_bytes()
    pa = dict(base)
    pa["final_norm"] = base["final_norm"] * 1.01
    mgr.save(1, pa, {"step": jnp.int32(0)})
    b1 = store_bytes()
    pb = dict(base)
    pb["embed"] = base["embed"].at[0].add(0.1)
    mgr.save(2, pb, {"step": jnp.int32(0)})
    b2 = store_bytes()
    assert b1 - b0 < b0 / 20             # tenant A: tiny delta
    assert b2 - b1 < b0 / 20             # tenant B: tiny delta


def test_data_pipeline_deterministic_restart():
    ds = SyntheticTokens(vocab=1000, batch=4, seq=16, seed=3)
    b5a = ds.batch_at(5)
    ds2 = SyntheticTokens(vocab=1000, batch=4, seq=16, seed=3)
    b5b = ds2.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(b5a["tokens"], ds.batch_at(6)["tokens"])
    np.testing.assert_array_equal(b5a["labels"][:, :-1],
                                  b5a["tokens"][:, 1:])
