"""Fault tolerance walkthrough: train, kill mid-run, lose devices, rebuild a
smaller mesh, reshard-restore from the layered store, and continue —
bit-identical to an uninterrupted run when the mesh is unchanged, and
loss-continuous when resharded.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.ft import DeadlineSkipper, shrink_mesh_shape
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, apply_update, init_opt_state


def run(cfg, acfg, steps, start=0, params=None, opt=None, mgr=None,
        save_every=5):
    ds = SyntheticTokens(cfg.vocab, batch=8, seq=32, seed=2)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt, _ = apply_update(acfg, params, opt, grads)
        return params, opt, loss

    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
    losses = []
    for s in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if mgr and (s + 1) % save_every == 0:
            mgr.save(s + 1, jax.tree.map(np.asarray, params),
                     jax.tree.map(np.asarray, opt))
    if mgr:
        mgr.wait()
    return params, opt, losses


def main():
    cfg = get_smoke_config("musicgen-medium").replace(n_layers=3)
    acfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=100,
                       weight_decay=0.0)
    root = tempfile.mkdtemp(prefix="lc_elastic_")
    mgr = CheckpointManager(root, cfg.name,
                            CheckpointPolicy(incremental=True,
                                             async_write=False))

    print("run A: 10 uninterrupted steps")
    pa, _, la = run(cfg, acfg, 10)

    print("run B: 5 steps -> simulated crash -> restore -> 5 more")
    run(cfg, acfg, 5, mgr=mgr, save_every=5)
    restored = mgr.restore()
    assert restored is not None
    p, o, s0 = restored
    print(f"  restored at step {s0}")
    pb, _, lb = run(cfg, acfg, 10, start=s0,
                    params=jax.tree.map(jnp.asarray, p),
                    opt=jax.tree.map(jnp.asarray, o))
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    print(f"  bitwise identical to run A: {same}")
    assert same

    print("elastic: 256 devices -> lose 32 -> new mesh", end=" ")
    new_shape = shrink_mesh_shape(alive_devices=224, model=16)
    print(f"{new_shape} (data axis shrunk, model axis intact)")

    print("straggler mitigation: host 2 slow for 3 steps ->")
    sk = DeadlineSkipper(n_hosts=4, factor=2.0, cordon_after=3)
    for t in range(3):
        inc = sk.decide({0: 1.0, 1: 1.05, 2: 9.0, 3: 0.95})
    print(f"  include={inc}  cordoned={sk.stats.cordoned}")
    print("elastic_restart OK")


if __name__ == "__main__":
    main()
