"""The paper's sweet spot in a training workflow: prompt-tuning /
adapter-style fine-tuning where each step touches a tiny, EARLY slice of
the state (soft-prompt embedding rows). The Docker-baseline checkpointer
falls through and re-serializes every downstream layer; injection writes
only the changed chunks + re-keys.

    PYTHONPATH=src python examples/finetune_lora_ckpt.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, apply_update, init_opt_state


def main():
    cfg = get_smoke_config("gemma-2b").replace(n_layers=4, d_model=128,
                                               d_ff=256, vocab=4096)
    params = init_params(cfg, jax.random.PRNGKey(0))
    total_mb = sum(np.asarray(l).nbytes
                   for l in jax.tree.leaves(params)) / 1e6
    print(f"backbone: {total_mb:.1f} MB; tuning 8 soft-prompt embedding "
          "rows (prompt-tuning), backbone frozen")

    # trainable = 8 soft-prompt embedding rows; backbone frozen.
    # The embedding is the FIRST content layer of the checkpoint image, so
    # the Docker-baseline save falls through everything below it.
    acfg = AdamWConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=100,
                       weight_decay=0.0)
    n_soft = 8
    trainable = {"soft": params["embed"][:n_soft]}
    opt = init_opt_state(trainable)

    @jax.jit
    def step(trainable, opt, frozen, batch):
        def loss_of(t):
            p = dict(frozen)
            p["embed"] = jnp.concatenate(
                [t["soft"].astype(p["embed"].dtype),
                 p["embed"][n_soft:]], axis=0)
            return loss_fn(cfg, p, batch)
        (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(trainable)
        trainable, opt, _ = apply_update(acfg, trainable, opt, grads)
        return trainable, opt, loss

    ds = SyntheticTokens(cfg.vocab, batch=8, seq=64, seed=1)
    results = {}
    for mode in ("full", "incremental"):
        ckpt_dir = tempfile.mkdtemp(prefix=f"lc_lora_{mode}_")
        mgr = CheckpointManager(
            ckpt_dir, cfg.name,
            CheckpointPolicy(incremental=(mode == "incremental"),
                             async_write=False, chunk_bytes=16 << 10))
        t = dict(trainable)
        o = jax.tree.map(lambda a: a, opt)
        frozen = dict(params)

        def assemble(t):
            p = dict(frozen)
            p["embed"] = jnp.concatenate(
                [t["soft"].astype(p["embed"].dtype),
                 p["embed"][n_soft:]], axis=0)
            return p

        mgr.save(0, assemble(t), {"step": jnp.int32(0)})
        saved_bytes, saved_ms = [], []
        for s in range(8):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
            t, o, loss = step(t, o, frozen, batch)
            full_params = assemble(t)
            rep = mgr.save(s + 1, jax.tree.map(np.asarray, full_params),
                           {"step": jnp.int32(s + 1)})
            saved_bytes.append(rep.bytes_serialized)
            saved_ms.append(rep.wall_seconds * 1e3)
        results[mode] = (np.mean(saved_bytes), np.mean(saved_ms))
        print(f"{mode:12s}: {np.mean(saved_bytes) / 1e6:8.2f} MB/save, "
              f"{np.mean(saved_ms):7.1f} ms/save")
    speed = results["full"][1] / results["incremental"][1]
    shrink = results["full"][0] / max(results["incremental"][0], 1)
    print(f"\nincremental injection: {speed:.0f}x faster, "
          f"{shrink:.0f}x fewer bytes per checkpoint")
    assert results["incremental"][0] < results["full"][0] / 10


if __name__ == "__main__":
    main()
