"""Multi-tenant serving with layer dedup — Docker's `FROM ubuntu` reuse for
model weights: N fine-tuned variants share base layers in one store; each
variant costs O(its delta) in storage, and switching variants reloads only
changed chunks.

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Engine


def store_bytes(mgr):
    total = 0
    for dp, _, fs in os.walk(os.path.join(mgr.store.root, "blobs")):
        for f in fs:
            total += os.path.getsize(os.path.join(dp, f))
    return total


def main():
    cfg = get_smoke_config("mixtral-8x7b")
    base = init_params(cfg, jax.random.PRNGKey(0))
    root = tempfile.mkdtemp(prefix="lc_tenants_")
    mgr = CheckpointManager(root, cfg.name,
                            CheckpointPolicy(incremental=True,
                                             async_write=False, keep=100))
    mgr.save(0, base, {"step": jnp.int32(0)})
    b0 = store_bytes(mgr)
    print(f"base image: {b0 / 1e6:.2f} MB")

    # three tenants fine-tune different tiny pieces
    tenants = {}
    deltas = [("final_norm", lambda p: p["final_norm"] * 2.0),
              ("embed", lambda p: p["embed"] + 0.5 * jnp.sign(p["embed"])),
              ("final_norm", lambda p: p["final_norm"] * 0.5)]
    for i, (leaf, fn) in enumerate(deltas, start=1):
        variant = dict(base)
        variant[leaf] = fn(base)
        before = store_bytes(mgr)
        mgr.save(i, variant, {"step": jnp.int32(i)})
        tenants[f"tenant{i}"] = i
        print(f"tenant{i}: +{(store_bytes(mgr) - before) / 1e3:.1f} KB "
              f"(delta on '{leaf}')")

    naive = b0 * (1 + len(deltas))
    print(f"store total: {store_bytes(mgr) / 1e6:.2f} MB "
          f"(naive per-tenant copies: {naive / 1e6:.2f} MB)")

    # serve two tenants and show they diverge from the same prompts
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(7), (2, 12), 0, cfg.vocab))
    outs = {}
    for name, step in list(tenants.items())[:2]:
        p, _, _ = mgr.restore(step)
        eng = Engine(cfg, jax.tree.map(jnp.asarray, p), max_len=48)
        outs[name] = eng.generate(prompts, steps=8).tokens
        print(f"{name} serve:", outs[name][0].tolist())
    print("multitenant OK")


if __name__ == "__main__":
    main()
