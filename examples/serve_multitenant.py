"""Multi-tenant fleet serving with a cross-image blob universe — Docker's
`FROM ubuntu` reuse for model weights, end to end: T fine-tuned variants
are separate IMAGES forked from one base (`CheckpointManager(image=...,
base_image=..., store=...)`), sharing base layers in one store; each
tenant costs O(its adapter) in storage, and `replicate_fanout` to serving
replicas that already hold the base ships ONLY the adapter delta — the
`FanoutStats` wire accounting printed below proves it.

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.configs import get_smoke_config
from repro.core import LayerStore, push_delta, replicate_fanout
from repro.models import init_params
from repro.serve import Engine


def blob_bytes(root):
    total = 0
    for dp, _, fs in os.walk(os.path.join(root, "blobs")):
        for f in fs:
            total += os.path.getsize(os.path.join(dp, f))
    return total


def main():
    cfg = get_smoke_config("mixtral-8x7b")
    base = init_params(cfg, jax.random.PRNGKey(0))
    root = tempfile.mkdtemp(prefix="lc_tenants_")
    policy = CheckpointPolicy(incremental=True, async_write=False, keep=100)

    # ---- the trainer side: one base image, T tenant images, ONE store
    base_mgr = CheckpointManager(os.path.join(root, "train"), cfg.name,
                                 policy, image="base-model")
    base_mgr.save(0, base, {"step": jnp.int32(0)})
    tag = base_mgr.tag_of(0)
    store = base_mgr.store
    b0 = blob_bytes(store.root)
    print(f"base image: {b0 / 1e6:.2f} MB")

    deltas = [("final_norm", lambda p: p["final_norm"] * 2.0),
              ("embed", lambda p: p["embed"] + 0.5 * jnp.sign(p["embed"])),
              ("final_norm", lambda p: p["final_norm"] * 0.5)]
    tenant_mgrs = {}
    for i, (leaf, fn) in enumerate(deltas, start=1):
        variant = dict(base)
        variant[leaf] = fn(base)
        mgr = CheckpointManager("", cfg.name, policy,
                                image=f"tenant{i}",
                                base_image=("base-model", tag),
                                store=store)     # the shared blob universe
        before = blob_bytes(store.root)
        rep = mgr.save(0, variant, {"step": jnp.int32(0)})
        tenant_mgrs[f"tenant{i}"] = mgr
        print(f"tenant{i}: +{(blob_bytes(store.root) - before) / 1e3:.1f} KB"
              f" on disk (delta on '{leaf}', "
              f"{rep.layers_cached} base layers reused by id)")

    naive = b0 * (1 + len(deltas))
    print(f"store total: {blob_bytes(store.root) / 1e6:.2f} MB "
          f"(naive per-tenant copies: {naive / 1e6:.2f} MB)")

    # ---- the fleet side: replicas are pre-seeded with the BASE image
    # only; fanning each tenant to them ships just the adapter delta,
    # because the have-set answers from the replica's whole committed
    # namespace (the base image vouches for every backbone blob).
    replicas = [LayerStore(os.path.join(root, f"replica{j}"))
                for j in range(2)]
    for r in replicas:
        seeded = push_delta(store, r, "base-model", tag)
        print(f"seed {os.path.basename(r.root)} with base: "
              f"{seeded.bytes_sent / 1e6:.2f} MB on the wire")

    for name in tenant_mgrs:
        before = [blob_bytes(r.root) for r in replicas]
        fan = replicate_fanout(store, replicas, name, tag)
        assert fan.ok, [r.error for r in fan.replicas]
        wire = max(r.stats.bytes_sent for r in fan.replicas)
        disk = max(blob_bytes(r.root) - b for r, b in zip(replicas, before))
        print(f"fan {name} -> {len(replicas)} base-holding replicas: "
              f"rounds={fan.negotiation_rounds} "
              f"source_reads={fan.source_blob_reads} "
              f"wire<= {wire / 1e3:.1f} KB/replica "
              f"disk<= {disk / 1e3:.1f} KB/replica "
              f"(base would be {b0 / 1e6:.2f} MB)")

    # ---- the serving side: two tenants served FROM A REPLICA diverge on
    # the same prompts (each replica now holds base + all tenants,
    # deduped in its own cross-image store)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(7), (2, 12), 0, cfg.vocab))
    serve_store = replicas[0]
    for name in list(tenant_mgrs)[:2]:
        flat = serve_store.load_image_payload(name, tag)
        from repro.ckpt.manager import unflatten_tree
        tree = unflatten_tree({k[len("params/"):]: v
                               for k, v in flat.items()
                               if k.startswith("params/")})
        eng = Engine(cfg, jax.tree.map(jnp.asarray, tree), max_len=48)
        toks = eng.generate(prompts, steps=8).tokens
        print(f"{name} serve:", toks[0].tolist())
    print("multitenant OK")


if __name__ == "__main__":
    main()
