"""Quickstart: train a small model end-to-end with incremental (code
injection) checkpointing, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, CheckpointPolicy
from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, apply_update, init_opt_state
from repro.serve import Engine


def main():
    cfg = get_smoke_config("yi-6b").replace(n_layers=4)
    print(f"arch={cfg.name} (reduced) params={cfg.param_count() / 1e6:.2f}M")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    acfg = AdamWConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=200,
                       weight_decay=0.0)

    ckpt_dir = tempfile.mkdtemp(prefix="lc_quickstart_")
    mgr = CheckpointManager(ckpt_dir, cfg.name,
                            CheckpointPolicy(incremental=True,
                                             async_write=False))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt, stats = apply_update(acfg, params, opt, grads)
        return params, opt, loss

    ds = SyntheticTokens(cfg.vocab, batch=8, seq=64, seed=0)
    for s in range(120):
        b = ds.batch_at(s)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, batch)
        if s % 20 == 0:
            print(f"step {s:4d}  loss {float(loss):.4f}")
        if (s + 1) % 40 == 0:
            rep = mgr.save(s + 1, jax.tree.map(np.asarray, params),
                           jax.tree.map(np.asarray, opt))
            print(f"  [ckpt] step {s + 1}: injected={rep.layers_injected} "
                  f"rekeyed={rep.layers_rekeyed} "
                  f"bytes={rep.bytes_serialized / 1e6:.1f}MB "
                  f"({rep.wall_seconds * 1e3:.0f}ms)")

    print("\nserving greedy samples from the trained weights:")
    eng = Engine(cfg, params, max_len=96)
    prompts = np.asarray(ds.batch_at(0)["tokens"][:2, :16])
    res = eng.generate(prompts, steps=12)
    print("generated:", res.tokens.tolist())
    print("quickstart OK")


if __name__ == "__main__":
    main()
