"""Shared neural-net primitives (pure jnp, SPMD-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., d_in) @ w: (d_in, d_out) in compute dtype."""
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def proj_heads(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., d) @ w: (d, H, Dh) -> (..., H, Dh).

    Head-structured weights keep TP sharding on the head axis explicit —
    no flat-dim reshape for the SPMD partitioner to second-guess.
    """
    return jnp.einsum("...d,dhk->...hk", x, w.astype(x.dtype))


def unproj_heads(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., H, Dh) @ w: (H, Dh, d) -> (..., d)."""
    return jnp.einsum("...hk,hkd->...d", x, w.astype(x.dtype))


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, act: str = "swiglu") -> jax.Array:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    if act == "swiglu":
        h = jax.nn.silu(g) * u
    elif act == "geglu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        raise ValueError(act)
    return dense(h, w_down)


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S).

    Rotates the full last dim (D must be even), interleaved-pair convention.
    """
    D = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(D, theta))          # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == ang.ndim + 1:                          # has head axis
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- init
def trunc_normal(key, shape, std, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return trunc_normal(key, (d_in, d_out), d_in ** -0.5, dtype)
