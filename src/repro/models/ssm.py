"""Mamba-2 (SSD — state-space duality) in pure jnp.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks of
length Q; within a chunk the recurrence is computed as a masked
attention-like quadratic form (MXU-friendly), and a (B, H, P, N) state is
carried across chunks with a lax.scan. Einsums keep the head-dim P as a free
axis so TP sharding over P is local.

``ssd_reference`` is the exact sequential recurrence (the oracle for both
the chunked path and the kernels/ssd_scan Pallas kernel).

Shapes:
    x   (B, S, H, P)    inputs per head
    dt  (B, S, H)       softplus-ed step sizes
    A   (H,)            negative decay rates
    Bc  (B, S, G, N)    input projections (groups broadcast over heads)
    Cc  (B, S, G, N)    output projections
    D   (H,)            skip connection
state: (B, H, P, N) float32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _expand_groups(t: jax.Array, n_heads: int) -> jax.Array:
    """(B, ..., G, N) -> (B, ..., H, N) by repeating each group."""
    G = t.shape[-2]
    if G == n_heads:
        return t
    return jnp.repeat(t, n_heads // G, axis=-2)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
                Cc: jax.Array, D: jax.Array, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bc.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk

    G = Bc.shape[-2]
    rep = H // G
    dtf = dt.astype(jnp.float32)
    da = dtf * A.astype(jnp.float32)    # (B, S, H) — log-decay per step

    # reshape into chunks (B/C stay GROUPED — 1/rep the bytes of expansion)
    def ck(t):
        return t.reshape(B, nc, chunk, *t.shape[2:])
    xc, dtc = ck(x), ck(dtf)
    Bcc, Ccc = ck(Bc), ck(Cc)
    L = jnp.cumsum(ck(da), axis=2)      # (B, nc, Q, H) inclusive cum log-decay

    @jax.checkpoint     # recompute chunk internals in backward: saves only
    def body(h, inp):   # the (B,H,P,N) carry per chunk, not the QxQ scores
        xq, dtq, Bq, Cq, Lq = inp
        Bf, Cf = Bq.astype(jnp.float32), Cq.astype(jnp.float32)
        xf = xq.astype(jnp.float32)
        # intra-chunk quadratic form, grouped:
        # scores_hij = (C_gi . B_gj) * exp(L_hi - L_hj) * dt_hj  for i >= j
        cb = jnp.einsum("bign,bjgn->bgij", Cf, Bf)         # (B, G, i, j)
        decay = Lq[:, :, None, :] - Lq[:, None, :, :]      # (B, i, j, H)
        ii = jnp.arange(Lq.shape[1])
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        M = jnp.where(causal, jnp.exp(decay), 0.0) * \
            dtq[:, None, :, :]                             # (B, i, j, H)
        M = M.transpose(0, 3, 1, 2)                        # (B, H, i, j)
        cb_h = jnp.repeat(cb, rep, axis=1) if rep > 1 else cb  # (B,H,i,j)
        y_intra = jnp.einsum("bhij,bjhp->bihp", cb_h * M, xf)
        # inter-chunk: contribution of the incoming state
        y_inter = jnp.einsum("bign,bih,bhpn->bihp",
                             Cf, jnp.exp(Lq), h) if G == 1 else \
            jnp.einsum("bihn,bhpn->bihp",
                       jnp.repeat(Cf, rep, axis=2) *
                       jnp.exp(Lq)[..., None], h)
        # state update: h' = exp(L_Q) h + sum_j exp(L_Q - L_j) dt_j B_j x_j
        Lq_last = Lq[:, -1][:, None]                       # (B, 1, H)
        w = jnp.exp(Lq_last - Lq) * dtq                    # (B, Q, H)
        h_new = jnp.exp(Lq_last[:, 0])[..., None, None] * h + \
            (jnp.einsum("bjgn,bjh,bjhp->bhpn", Bf, w, xf) if G == 1 else
             jnp.einsum("bjhn,bjhp->bhpn",
                        jnp.repeat(Bf, rep, axis=2) * w[..., None], xf))
        return h_new, (y_intra + y_inter)

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    # scan over chunks
    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          Bcc.transpose(1, 0, 2, 3, 4), Ccc.transpose(1, 0, 2, 3, 4),
          L.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_reference(x, dt, A, Bc, Cc, D, h0=None):
    """Exact sequential recurrence — oracle (small shapes only)."""
    B, S, H, P = x.shape
    N = Bc.shape[-1]
    Bh = _expand_groups(Bc, H).astype(jnp.float32)
    Ch = _expand_groups(Cc, H).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp       # (B,H,P) (B,H) (B,H,N) (B,H,N)
        a = jnp.exp(dt_t * A.astype(jnp.float32))          # (B,H)
        h = h * a[..., None, None] + jnp.einsum(
            "bhn,bhp->bhpn", B_t * dt_t[..., None], x_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    h, ys = jax.lax.scan(step, h, xs)
    y = ys.transpose(1, 0, 2, 3) + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D):
    """One-token recurrence. h: (B,H,P,N) f32; x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,G,N). Returns (h', y (B,H,P))."""
    H = x_t.shape[1]
    B_t = _expand_groups(B_t, H).astype(jnp.float32)
    C_t = _expand_groups(C_t, H).astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32))
    h = h * a[..., None, None] + jnp.einsum("bhn,bhp->bhpn",
                                            B_t * dtf[..., None], xf)
    y = jnp.einsum("bhpn,bhn->bhp", h, C_t) + xf * \
        D.astype(jnp.float32)[None, :, None]
    return h, y.astype(x_t.dtype)


# ------------------------------------------------------------------ conv1d
def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, *C); w: (*C, K); b: (*C,).

    The channel block *C may be multi-dim (e.g. (H, P)) so TP sharding on a
    channel sub-axis stays structural.
    """
    K = w.shape[-1]
    S = x.shape[1]
    pad = [(0, 0), (K - 1, 0)] + [(0, 0)] * (x.ndim - 2)
    xp = jnp.pad(x, pad)
    y = sum(xp[:, k:k + S] * w[..., k].astype(x.dtype) for k in range(K))
    return y + b.astype(x.dtype)


def causal_conv_step(state: jax.Array, x_t: jax.Array, w: jax.Array,
                     b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """state: (B, K-1, *C) last inputs; x_t: (B, *C). -> (state', y)."""
    full = jnp.concatenate([state, x_t[:, None]], axis=1)   # (B, K, *C)
    wt = jnp.moveaxis(w, -1, 0).astype(x_t.dtype)           # (K, *C)
    y = jnp.sum(full * wt[None], axis=1) + b.astype(x_t.dtype)
    return full[:, 1:], y
