from .config import ModelConfig
from .model import (decode_step, init_cache, init_params, loss_fn,
                    padded_vocab, param_specs, prefill)

__all__ = ["ModelConfig", "decode_step", "init_cache", "init_params",
           "loss_fn", "padded_vocab", "param_specs", "prefill"]
