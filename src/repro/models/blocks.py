"""Per-family transformer blocks: init, train/prefill apply, decode step.

Every family exposes:
    init_block(cfg, key)                      -> params pytree (one layer)
    apply_block(cfg, p, x, positions)         -> (x', aux, cache_entry|None)
    decode_block(cfg, p, cache, x_t, pos)     -> (cache', x_t')
    init_layer_cache(cfg, batch, cache_len)   -> per-layer cache pytree

Weights are head-structured (d, H, Dh) / (H, Dh, d) — TP sharding lives on
an explicit head (or head-dim) axis, never on a flattened dim the SPMD
partitioner would have to re-factor. ``constrain(x, name)`` pins named
activations to the recipe's PartitionSpec (no-op outside a launcher).

``apply_block`` serves both train (cache ignored) and prefill (cache
collected). Caches hold ungrouped K/V (KVH heads); SWA archs use a ring
buffer of ``window`` slots.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .attention import attention, attention_decode
from .config import ModelConfig
from .layers import (apply_rope, dense, dense_init, proj_heads, rms_norm,
                     trunc_normal, unproj_heads)
from .moe import moe_ffn
from .ssm import (causal_conv, causal_conv_step, ssd_chunked,
                  ssd_decode_step)


# =========================================================== shared helpers
def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    return k if n_rep == 1 else jnp.repeat(k, n_rep, axis=2)


def _head_init(key, d, H, Dh, dtype):
    return trunc_normal(key, (d, H, Dh), d ** -0.5, dtype)


def _qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array):
    q = proj_heads(x, p["wq"])
    k = proj_heads(x, p["wk"])
    v = proj_heads(x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return constrain(q, "act_q"), constrain(k, "act_kv"), \
        constrain(v, "act_kv")


def _self_attention(cfg: ModelConfig, p: Dict, h: jax.Array,
                    positions: jax.Array):
    """-> (attn output (B,S,d), k, v)."""
    q, k, v = _qkv(cfg, p, h, positions)
    rep = cfg.n_heads // cfg.n_kv_heads
    kr = constrain(_repeat_kv(k, rep), "act_kv_rep")
    vr = constrain(_repeat_kv(v, rep), "act_kv_rep")
    o = attention(q, kr, vr, causal=True, window=cfg.window,
                  impl=cfg.attn_impl, kv_block=cfg.kv_block,
                  q_block=cfg.q_block, score_dtype=cfg.score_dtype)
    o = constrain(o, "act_q")
    return unproj_heads(o, p["wo"]), k, v


def _attn_init(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.param_dtype
    H, KVH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": _head_init(ks[0], d, H, Dh, dt),
        "wk": _head_init(ks[1], d, KVH, Dh, dt),
        "wv": _head_init(ks[2], d, KVH, Dh, dt),
        "wo": trunc_normal(ks[3], (H, Dh, d), (H * Dh) ** -0.5, dt),
    }


def _mlp_init(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 3)
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        "w_gate": dense_init(ks[0], d, cfg.d_ff, dt),
        "w_up": dense_init(ks[1], d, cfg.d_ff, dt),
        "w_down": dense_init(ks[2], cfg.d_ff, d, dt),
    }


def _mlp(cfg: ModelConfig, p: Dict, h: jax.Array) -> jax.Array:
    g = constrain(dense(h, p["w_gate"]), "act_ffh")
    u = constrain(dense(h, p["w_up"]), "act_ffh")
    if cfg.act == "swiglu":
        hh = jax.nn.silu(g) * u
    else:
        hh = jax.nn.gelu(g, approximate=True) * u
    return dense(hh, p["w_down"])


def _ring_tail(k: jax.Array, C: int) -> jax.Array:
    """Last C positions of k (B,S,...) laid out ring-style (slot = pos % C)
    so decode's ``pos % C`` insertion continues consistently."""
    S = k.shape[1]
    if S < C:
        pad = [(0, 0), (C - S, 0)] + [(0, 0)] * (k.ndim - 2)
        return jnp.pad(k, pad)
    tail = k[:, -C:]
    shift = S % C
    return jnp.roll(tail, shift, axis=1) if shift else tail


def _kv_cache_init(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.dtype(cfg.compute_dtype)),
            "v": jnp.zeros(shape, jnp.dtype(cfg.compute_dtype))}


def _cache_positions(cache_len: int, pos: jax.Array) -> jax.Array:
    """Absolute position held in each ring slot; invalid slots get INT_MAX."""
    s = jnp.arange(cache_len)
    cand = pos - jnp.mod(pos - s, cache_len)
    return jnp.where(cand >= 0, cand, jnp.iinfo(jnp.int32).max)


def _kv_cache_insert(cache: Dict, k_t: jax.Array, v_t: jax.Array,
                     pos: jax.Array) -> Dict:
    C = cache["k"].shape[1]
    slot = jnp.mod(pos, C)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_t, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_t, slot, axis=1)
    return {"k": constrain(k, "cache_kv"), "v": constrain(v, "cache_kv")}


def _attn_decode(cfg: ModelConfig, p: Dict, cache: Dict, x_t: jax.Array,
                 pos: jax.Array) -> Tuple[Dict, jax.Array]:
    B = x_t.shape[0]
    x1 = x_t[:, None]                                       # (B, 1, d)
    q = proj_heads(x1, p["wq"])
    k = proj_heads(x1, p["wk"])
    v = proj_heads(x1, p["wv"])
    pos_b = jnp.broadcast_to(pos, (B, 1))
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)
    cache = _kv_cache_insert(cache, k, v, pos)
    cpos = _cache_positions(cache["k"].shape[1], pos)
    o = attention_decode(q, cache["k"], cache["v"], cpos, pos,
                         window=cfg.window)
    y = unproj_heads(o, p["wo"])[:, 0]
    return cache, y


# ================================================================== dense
def init_dense_block(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "attn_norm": jnp.ones((d,), jnp.float32),
        "mlp_norm": jnp.ones((d,), jnp.float32),
        **_attn_init(cfg, ks[0]),
        **_mlp_init(cfg, ks[1]),
    }


def apply_dense_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                      positions: jax.Array, collect_cache: bool = False):
    from jax.ad_checkpoint import checkpoint_name
    # "act_block_in": under tp_sp this is THE Megatron-SP gather point —
    # one all-gather per block half, shared by every projection after it.
    h = constrain(rms_norm(x, p["attn_norm"], cfg.rms_eps), "act_block_in")
    a, k, v = _self_attention(cfg, p, h, positions)
    a = checkpoint_name(a, "block_out")     # post-psum: remat="outputs"
    x = constrain(x + a, "act_hidden")      # saves these, skips recompute
    h = constrain(rms_norm(x, p["mlp_norm"], cfg.rms_eps), "act_block_in")
    m = checkpoint_name(_mlp(cfg, p, h), "block_out")
    x = constrain(x + m, "act_hidden")
    cache = None
    if collect_cache:
        C = cfg.cache_len(x.shape[1])
        cache = {"k": _ring_tail(k, C), "v": _ring_tail(v, C)}
    return x, jnp.float32(0.0), cache


def decode_dense_block(cfg: ModelConfig, p: Dict, cache: Dict,
                       x_t: jax.Array, pos: jax.Array):
    h = rms_norm(x_t, p["attn_norm"], cfg.rms_eps)
    cache, a = _attn_decode(cfg, p, cache, h, pos)
    x_t = x_t + a
    h = rms_norm(x_t, p["mlp_norm"], cfg.rms_eps)
    x_t = x_t + _mlp(cfg, p, h)
    return cache, x_t


# ==================================================================== moe
def init_moe_block(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 5)
    d, dt = cfg.d_model, cfg.param_dtype
    E, fe = cfg.n_experts, cfg.d_ff_expert
    return {
        "attn_norm": jnp.ones((d,), jnp.float32),
        "mlp_norm": jnp.ones((d,), jnp.float32),
        **_attn_init(cfg, ks[0]),
        "router": dense_init(ks[1], d, E, jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[2], -2, 2, (E, d, fe))
                   * d ** -0.5).astype(dt),
        "w_up": (jax.random.truncated_normal(ks[3], -2, 2, (E, d, fe))
                 * d ** -0.5).astype(dt),
        "w_down": (jax.random.truncated_normal(ks[4], -2, 2, (E, fe, d))
                   * fe ** -0.5).astype(dt),
    }


def _moe(cfg: ModelConfig, p: Dict, h2d: jax.Array):
    return moe_ffn(h2d, p["router"], p["w_gate"], p["w_up"], p["w_down"],
                   top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                   act=cfg.act)


def _moe_local(cfg: ModelConfig, p: Dict, h: jax.Array, spec):
    """Fully-local MoE: shard_map over the token axes with REPLICATED
    expert weights — each shard routes its own tokens into its own
    capacity buffer; zero collectives inside the MoE (the scatter/sort/
    psum pathologies of the SPMD-auto path disappear). Used when the
    rule table provides "moe_local" (small-expert archs under sp)."""
    from jax.sharding import PartitionSpec as P
    from ..sharding.ctx import current_mesh, shard_map_fn
    shard_map = shard_map_fn()
    mesh = current_mesh()
    axes = tuple(a for e in tuple(spec) if e is not None
                 for a in (e if isinstance(e, tuple) else (e,)))

    def body(hb, router, wg, wu, wd):
        B, S, d = hb.shape
        y, aux = moe_ffn(hb.reshape(B * S, d), router, wg, wu, wd,
                         top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, act=cfg.act)
        aux = jax.lax.pmean(aux, axes)
        return y.reshape(B, S, d), aux

    specs = dict(in_specs=(spec, P(), P(), P(), P()),
                 out_specs=(spec, P()))
    try:
        fn = shard_map(body, mesh=mesh, check_rep=False, **specs)
    except TypeError:     # newer jax renamed check_rep -> check_vma
        fn = shard_map(body, mesh=mesh, check_vma=False, **specs)
    return fn(h, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def apply_moe_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                    positions: jax.Array, collect_cache: bool = False):
    B, S, d = x.shape
    h = rms_norm(x, p["attn_norm"], cfg.rms_eps)
    a, k, v = _self_attention(cfg, p, h, positions)
    x = constrain(x + a, "act_hidden")
    h = rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    from ..sharding.ctx import _RULES
    rules = _RULES.get() or {}
    if rules.get("moe_local") is not None:
        # fully-local dispatch (see _moe_local)
        y3, aux = _moe_local(cfg, p, h, rules["moe_local"])
        x = constrain(x + y3, "act_hidden")
        return x, aux, ({"k": _ring_tail(k, cfg.cache_len(S)),
                         "v": _ring_tail(v, cfg.cache_len(S))}
                        if collect_cache else None)
    # Otherwise: pin the MoE input layout (all-gather in, reduce-scatter
    # out — the Megatron-SP MoE pattern) so flattening (B,S) never mixes
    # sharded dims inside the sort-based dispatch.
    h = constrain(h, "act_moe_in")
    y, aux = _moe(cfg, p, h.reshape(B * S, d))
    x = constrain(x + constrain(y.reshape(B, S, d), "act_moe_out"),
                  "act_hidden")
    cache = None
    if collect_cache:
        C = cfg.cache_len(S)
        cache = {"k": _ring_tail(k, C), "v": _ring_tail(v, C)}
    return x, aux, cache


def decode_moe_block(cfg: ModelConfig, p: Dict, cache: Dict,
                     x_t: jax.Array, pos: jax.Array):
    h = rms_norm(x_t, p["attn_norm"], cfg.rms_eps)
    cache, a = _attn_decode(cfg, p, cache, h, pos)
    x_t = x_t + a
    h = rms_norm(x_t, p["mlp_norm"], cfg.rms_eps)
    y, _ = _moe(cfg, p, h)
    return cache, x_t + y


# ==================================================================== mla
def init_mla_block(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 6)
    d, dt, H = cfg.d_model, cfg.param_dtype, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "attn_norm": jnp.ones((d,), jnp.float32),
        "mlp_norm": jnp.ones((d,), jnp.float32),
        "wq_a": dense_init(ks[0], d, qr, dt),
        "q_norm": jnp.ones((qr,), jnp.float32),
        "wq_b": _head_init(ks[1], qr, H, nope + rope, dt),
        "wkv_a": dense_init(ks[2], d, kr + rope, dt),
        "kv_norm": jnp.ones((kr,), jnp.float32),
        "wkv_b": _head_init(ks[3], kr, H, nope + vh, dt),
        "wo": trunc_normal(ks[4], (H, vh, d), (H * vh) ** -0.5, dt),
        **_mlp_init(cfg, ks[5]),
    }


def _mla_qkv(cfg: ModelConfig, p: Dict, h: jax.Array, positions: jax.Array):
    """-> q (B,S,H,nope+rope), c_kv (B,S,kr) normed, k_rope (B,S,rope)."""
    nope = cfg.qk_nope_dim
    qa = rms_norm(dense(h, p["wq_a"]), p["q_norm"], cfg.rms_eps)
    q = proj_heads(qa, p["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "act_q")
    kv_a = dense(h, p["wkv_a"])
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(kv_a[..., cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)
    return q, c_kv, k_rope


def apply_mla_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                    positions: jax.Array, collect_cache: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = rms_norm(x, p["attn_norm"], cfg.rms_eps)
    q, c_kv, k_rope = _mla_qkv(cfg, p, h, positions)
    # expand keys/values from the latent (training path)
    kv = proj_heads(c_kv, p["wkv_b"])                       # (B,S,H,nope+vh)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, rope))],
        axis=-1)
    k = constrain(k, "act_q")
    o = attention(q, k, constrain(v, "act_q"), causal=True,
                  window=cfg.window, impl=cfg.attn_impl,
                  kv_block=cfg.kv_block, q_block=cfg.q_block,
                  scale=(nope + rope) ** -0.5,
                  score_dtype=cfg.score_dtype)
    x = constrain(x + unproj_heads(constrain(o, "act_q"), p["wo"]),
                  "act_hidden")
    h = rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    x = constrain(x + _mlp(cfg, p, h), "act_hidden")
    cache = None
    if collect_cache:
        cache = {"c_kv": c_kv, "k_rope": k_rope}
    return x, jnp.float32(0.0), cache


def mla_cache_init(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    dt = jnp.dtype(cfg.compute_dtype)
    return {"c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dt)}


def decode_mla_block(cfg: ModelConfig, p: Dict, cache: Dict,
                     x_t: jax.Array, pos: jax.Array):
    """Absorbed MLA decode: attention runs in latent space; the cache is the
    (kv_lora_rank + rope) latent — MLA's memory advantage."""
    B, d = x_t.shape
    nope, rope, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = rms_norm(x_t, p["attn_norm"], cfg.rms_eps)[:, None]     # (B,1,d)
    pos_b = jnp.broadcast_to(pos, (B, 1))
    q, c_kv, k_rope = _mla_qkv(cfg, p, h, pos_b)
    q_nope, q_rope = q[..., :nope], q[..., nope:]               # (B,1,H,·)
    C = cache["c_kv"].shape[1]
    slot = jnp.mod(pos, C)
    c_cache = constrain(jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv, slot, axis=1), "cache_latent")
    r_cache = constrain(jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope, slot, axis=1), "cache_latent")
    # absorb W_UK into q:   q_abs = q_nope @ W_UK^T  -> latent space
    w_uk = p["wkv_b"][..., :nope]                               # (kr,H,nope)
    w_uv = p["wkv_b"][..., nope:]                               # (kr,H,vh)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))                # (B,1,H,kr)
    s = jnp.einsum("bqhr,bcr->bhqc", q_abs,
                   c_cache.astype(jnp.float32)) + \
        jnp.einsum("bqhr,bcr->bhqc", q_rope.astype(jnp.float32),
                   r_cache.astype(jnp.float32))
    s = s * (nope + rope) ** -0.5
    cpos = _cache_positions(C, pos)
    s = jnp.where(cpos[None, None, None] <= pos, s, -1e30)
    pw = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqc,bcr->bqhr", pw, c_cache.astype(jnp.float32))
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv.astype(jnp.float32))
    y = unproj_heads(o.astype(x_t.dtype), p["wo"])[:, 0]
    x_t = x_t + y
    h2 = rms_norm(x_t, p["mlp_norm"], cfg.rms_eps)
    x_t = x_t + _mlp(cfg, p, h2)
    return {"c_kv": c_cache, "k_rope": r_cache}, x_t


# ==================================================================== ssm
def _ssm_dims(cfg: ModelConfig):
    di, N, G, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    return di, N, G, Hs, di // Hs


def init_ssm_core(cfg: ModelConfig, key) -> Dict:
    di, N, G, Hs, P = _ssm_dims(cfg)
    d, dt, K = cfg.d_model, cfg.param_dtype, cfg.conv_kernel
    ks = jax.random.split(key, 11)
    u = jax.random.uniform(ks[0], (Hs,), jnp.float32, 1e-3, 1e-1)
    dt_bias = u + jnp.log(-jnp.expm1(-u))       # inverse softplus
    return {
        "w_z": trunc_normal(ks[1], (d, Hs, P), d ** -0.5, dt),
        "w_x": trunc_normal(ks[2], (d, Hs, P), d ** -0.5, dt),
        "w_B": trunc_normal(ks[3], (d, G, N), d ** -0.5, dt),
        "w_C": trunc_normal(ks[4], (d, G, N), d ** -0.5, dt),
        "w_dt": trunc_normal(ks[5], (d, Hs), d ** -0.5, dt),
        "conv_x_w": (jax.random.normal(ks[6], (Hs, P, K)) / K).astype(dt),
        "conv_x_b": jnp.zeros((Hs, P), jnp.float32),
        "conv_B_w": (jax.random.normal(ks[7], (G, N, K)) / K).astype(dt),
        "conv_B_b": jnp.zeros((G, N), jnp.float32),
        "conv_C_w": (jax.random.normal(ks[8], (G, N, K)) / K).astype(dt),
        "conv_C_b": jnp.zeros((G, N), jnp.float32),
        "A_log": jnp.log(jax.random.uniform(ks[9], (Hs,), jnp.float32,
                                            1.0, 16.0)),
        "D": jnp.ones((Hs,), jnp.float32),
        "dt_bias": dt_bias,
        "gate_norm": jnp.ones((Hs, P), jnp.float32),
        "out_proj": trunc_normal(ks[10], (Hs, P, d), di ** -0.5, dt),
    }


def _gated_rms(y: jax.Array, z: jax.Array, scale: jax.Array,
               eps: float) -> jax.Array:
    """RMSNorm(y * silu(z)) jointly over the (H, P) channel block."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=(-2, -1), keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) *
            scale.astype(jnp.float32)).astype(y.dtype)


def apply_ssm_core(cfg: ModelConfig, p: Dict, h: jax.Array,
                   collect_cache: bool = False):
    """h: (B, S, d) normed input -> (y (B,S,d), cache|None)."""
    B, S, _ = h.shape
    di, N, G, Hs, P = _ssm_dims(cfg)
    z = constrain(proj_heads(h, p["w_z"]), "act_ssm")       # (B,S,H,P)
    x_pre = constrain(proj_heads(h, p["w_x"]), "act_ssm")
    B_pre = proj_heads(h, p["w_B"])                          # (B,S,G,N)
    C_pre = proj_heads(h, p["w_C"])
    dt = dense(h, p["w_dt"])                                 # (B,S,H)
    xs = jax.nn.silu(causal_conv(x_pre, p["conv_x_w"], p["conv_x_b"]))
    Bc = jax.nn.silu(causal_conv(B_pre, p["conv_B_w"], p["conv_B_b"]))
    Cc = jax.nn.silu(causal_conv(C_pre, p["conv_C_w"], p["conv_C_b"]))
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_final = ssd_chunked(xs, dtf, A, Bc, Cc, p["D"], chunk=cfg.ssm_chunk)
    y = _gated_rms(y, z, p["gate_norm"], cfg.rms_eps)
    out = unproj_heads(y, p["out_proj"])
    cache = None
    if collect_cache:
        K = cfg.conv_kernel
        cdt = jnp.dtype(cfg.compute_dtype)

        def tail(t):     # chronological last K-1 inputs (left-pad if short)
            if t.shape[1] >= K - 1:
                return t[:, -(K - 1):].astype(cdt)
            pad = [(0, 0), (K - 1 - t.shape[1], 0)] + \
                [(0, 0)] * (t.ndim - 2)
            return jnp.pad(t, pad).astype(cdt)

        cache = {"conv_x": tail(x_pre), "conv_B": tail(B_pre),
                 "conv_C": tail(C_pre), "h": h_final}
    return out, cache


def init_ssm_block(cfg: ModelConfig, key) -> Dict:
    return {"norm": jnp.ones((cfg.d_model,), jnp.float32),
            **init_ssm_core(cfg, key)}


def apply_ssm_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                    positions: jax.Array, collect_cache: bool = False):
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    y, cache = apply_ssm_core(cfg, p, h, collect_cache)
    return constrain(x + y, "act_hidden"), jnp.float32(0.0), cache


def ssm_cache_init(cfg: ModelConfig, batch: int, cache_len: int = 0) -> Dict:
    di, N, G, Hs, P = _ssm_dims(cfg)
    K = cfg.conv_kernel
    cdt = jnp.dtype(cfg.compute_dtype)
    return {"conv_x": jnp.zeros((batch, K - 1, Hs, P), cdt),
            "conv_B": jnp.zeros((batch, K - 1, G, N), cdt),
            "conv_C": jnp.zeros((batch, K - 1, G, N), cdt),
            "h": jnp.zeros((batch, Hs, P, N), jnp.float32)}


def decode_ssm_core(cfg: ModelConfig, p: Dict, cache: Dict, h: jax.Array):
    """h: (B, d) normed -> (cache', y (B, d))."""
    B, _ = h.shape
    di, N, G, Hs, P = _ssm_dims(cfg)
    z = proj_heads(h, p["w_z"])                              # (B,H,P)
    x_pre = proj_heads(h, p["w_x"])
    B_pre = proj_heads(h, p["w_B"])
    C_pre = proj_heads(h, p["w_C"])
    dt = dense(h, p["w_dt"])
    conv_x, xs = causal_conv_step(cache["conv_x"], x_pre, p["conv_x_w"],
                                  p["conv_x_b"])
    conv_B, Bc = causal_conv_step(cache["conv_B"], B_pre, p["conv_B_w"],
                                  p["conv_B_b"])
    conv_C, Cc = causal_conv_step(cache["conv_C"], C_pre, p["conv_C_w"],
                                  p["conv_C_b"])
    xs, Bc, Cc = jax.nn.silu(xs), jax.nn.silu(Bc), jax.nn.silu(Cc)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h_new, y = ssd_decode_step(cache["h"], xs, dtf, A, Bc, Cc, p["D"])
    y = _gated_rms(y, z, p["gate_norm"], cfg.rms_eps)
    out = unproj_heads(y, p["out_proj"])
    return {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
            "h": h_new}, out


def decode_ssm_block(cfg: ModelConfig, p: Dict, cache: Dict,
                     x_t: jax.Array, pos: jax.Array):
    h = rms_norm(x_t, p["norm"], cfg.rms_eps)
    cache, y = decode_ssm_core(cfg, p, cache, h)
    return cache, x_t + y


# ================================================================= hybrid
def init_hybrid_block(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "mlp_norm": jnp.ones((d,), jnp.float32),
        "attn_fuse_norm": jnp.ones((d,), jnp.float32),
        "ssm_fuse_norm": jnp.ones((d,), jnp.float32),
        "attn": _attn_init(cfg, ks[0]),
        "ssm": init_ssm_core(cfg, ks[1]),
        **_mlp_init(cfg, ks[2]),
    }


def apply_hybrid_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                       positions: jax.Array, collect_cache: bool = False):
    """Hymba-style: attention heads and SSM heads read the same input in
    parallel; outputs are RMS-normed and averaged (the paper's mean fusion)."""
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    attn_out, k, v = _self_attention(cfg, p["attn"], h, positions)
    ssm_out, ssm_cache = apply_ssm_core(cfg, p["ssm"], h, collect_cache)
    fused = 0.5 * (rms_norm(attn_out, p["attn_fuse_norm"], cfg.rms_eps) +
                   rms_norm(ssm_out, p["ssm_fuse_norm"], cfg.rms_eps))
    x = constrain(x + fused, "act_hidden")
    h2 = rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    x = constrain(x + _mlp(cfg, p, h2), "act_hidden")
    cache = None
    if collect_cache:
        C = cfg.cache_len(x.shape[1])
        cache = {"k": _ring_tail(k, C), "v": _ring_tail(v, C), **ssm_cache}
    return x, jnp.float32(0.0), cache


def hybrid_cache_init(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    return {**_kv_cache_init(cfg, batch, cache_len),
            **ssm_cache_init(cfg, batch)}


def decode_hybrid_block(cfg: ModelConfig, p: Dict, cache: Dict,
                        x_t: jax.Array, pos: jax.Array):
    h = rms_norm(x_t, p["norm"], cfg.rms_eps)
    kv_cache = {"k": cache["k"], "v": cache["v"]}
    kv_cache, attn_out = _attn_decode(cfg, p["attn"], kv_cache, h, pos)
    ssm_cache = {k2: cache[k2] for k2 in ("conv_x", "conv_B", "conv_C", "h")}
    ssm_cache, ssm_out = decode_ssm_core(cfg, p["ssm"], ssm_cache, h)
    fused = 0.5 * (rms_norm(attn_out, p["attn_fuse_norm"], cfg.rms_eps) +
                   rms_norm(ssm_out, p["ssm_fuse_norm"], cfg.rms_eps))
    x_t = x_t + fused
    h2 = rms_norm(x_t, p["mlp_norm"], cfg.rms_eps)
    x_t = x_t + _mlp(cfg, p, h2)
    return {**kv_cache, **ssm_cache}, x_t


# ============================================================== dispatch
FAMILY_INIT = {"dense": init_dense_block, "moe": init_moe_block,
               "mla": init_mla_block, "ssm": init_ssm_block,
               "hybrid": init_hybrid_block}
FAMILY_APPLY = {"dense": apply_dense_block, "moe": apply_moe_block,
                "mla": apply_mla_block, "ssm": apply_ssm_block,
                "hybrid": apply_hybrid_block}
FAMILY_DECODE = {"dense": decode_dense_block, "moe": decode_moe_block,
                 "mla": decode_mla_block, "ssm": decode_ssm_block,
                 "hybrid": decode_hybrid_block}


def init_block(cfg: ModelConfig, key):
    return FAMILY_INIT[cfg.family](cfg, key)


def apply_block(cfg, p, x, positions, collect_cache=False):
    return FAMILY_APPLY[cfg.family](cfg, p, x, positions, collect_cache)


def decode_block(cfg, p, cache, x_t, pos):
    return FAMILY_DECODE[cfg.family](cfg, p, cache, x_t, pos)


def init_layer_cache(cfg: ModelConfig, batch: int, cache_len: int):
    if cfg.family in ("dense", "moe"):
        return _kv_cache_init(cfg, batch, cache_len)
    if cfg.family == "mla":
        return mla_cache_init(cfg, batch, cache_len)
    if cfg.family == "ssm":
        return ssm_cache_init(cfg, batch)
    if cfg.family == "hybrid":
        return hybrid_cache_init(cfg, batch, cache_len)
    raise ValueError(cfg.family)
