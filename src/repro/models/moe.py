"""Mixture-of-Experts — sort-based capacity dispatch (Switch/Mixtral style).

The dispatch is *token-local*: it routes whatever token set it is given into
an (E, C, d) capacity buffer via sort + scatter, runs the expert FFNs as
batched einsums, and scatters results back. Under the production mesh the
block is invoked inside shard_map over the data axis (each data shard routes
its own tokens — no cross-device scatter), with expert weights TP-sharded on
their hidden dim over the model axis (psum over 'model' happens on the
*output* projection, same collective pattern as a dense TP FFN).

Tokens over capacity are dropped (standard capacity-factor routing); the
router aux loss (load-balancing, Switch eq. 4) is returned for the train
loss.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def route(x: jax.Array, w_router: jax.Array, top_k: int
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, d) -> (weights (T,k), experts (T,k) int32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, experts = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance aux: E * sum_e f_e * p_e
    E = w_router.shape[-1]
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(
        jnp.ones_like(experts.reshape(-1), jnp.float32))
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = E * jnp.sum(me * ce)
    return gate, experts, aux


def dispatch_indices(experts: jax.Array, n_experts: int, capacity: int
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based capacity assignment.

    experts: (T, k) int32 -> returns (slot (T*k,), keep (T*k,), token (T*k,))
    where slot = expert * capacity + position-within-expert for kept entries.
    """
    T, k = experts.shape
    flat = experts.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat, stable=True)                   # group by expert
    sorted_e = flat[order]
    # position within expert = index - start offset of that expert
    ones = jnp.ones_like(sorted_e)
    pos_in_sorted = jnp.cumsum(ones) - 1
    counts = jnp.zeros((n_experts,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(counts) - counts                     # exclusive
    pos_in_expert = pos_in_sorted - starts[sorted_e]
    keep_sorted = pos_in_expert < capacity
    slot_sorted = sorted_e * capacity + jnp.minimum(pos_in_expert,
                                                    capacity - 1)
    inv = jnp.argsort(order, stable=True)                    # undo sort
    return slot_sorted[inv], keep_sorted[inv], jnp.arange(T * k) // k


def moe_ffn(x: jax.Array, w_router: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25, act: str = "swiglu",
            psum_axis: Optional[str] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d); expert weights: (E, d, f) / (E, f, d).

    Returns (out (T, d), aux_loss). If ``psum_axis`` is given the caller is
    inside shard_map and w_down's output is partial-summed over that axis.
    """
    T, d = x.shape
    E = w_router.shape[-1]
    capacity = max(1, int(T * top_k * capacity_factor / E))

    gate, experts, aux = route(x, w_router, top_k)
    slot, keep, token = dispatch_indices(experts, E, capacity)

    # scatter tokens into the capacity buffer (dropped tokens write nowhere)
    buf = jnp.zeros((E * capacity, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[token], 0).astype(x.dtype)
    safe_slot = jnp.where(keep, slot, E * capacity - 1)
    buf = buf.at[safe_slot].add(jnp.where(keep[:, None], contrib, 0))
    buf = buf.reshape(E, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype))
    if act == "swiglu":
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g, approximate=True) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))
    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)
    y = y.reshape(E * capacity, d)

    # gather back with routing weights
    picked = jnp.where(keep[:, None], y[safe_slot], 0)
    weighted = picked * jnp.where(keep, gate.reshape(-1), 0)[:, None] \
        .astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[token].add(weighted)
    return out, aux


def moe_ffn_reference(x, w_router, w_gate, w_up, w_down, *, top_k,
                      act="swiglu"):
    """Dense oracle: every token through its top-k experts, no capacity
    drops. Tests compare moe_ffn against this with capacity_factor large
    enough that nothing drops."""
    gate, experts, aux = route(x, w_router, top_k)
    T, d = x.shape
    E = w_router.shape[-1]
    out = jnp.zeros((T, d), jnp.float32)
    for e in range(E):
        g = jnp.einsum("td,df->tf", x, w_gate[e].astype(x.dtype))
        u = jnp.einsum("td,df->tf", x, w_up[e].astype(x.dtype))
        h = jax.nn.silu(g) * u if act == "swiglu" else \
            jax.nn.gelu(g, approximate=True) * u
        y = jnp.einsum("tf,fd->td", h, w_down[e].astype(x.dtype))
        w_e = jnp.sum(jnp.where(experts == e, gate, 0.0), axis=-1)
        out = out + y.astype(jnp.float32) * w_e[:, None]
    return out.astype(x.dtype), aux
