"""ModelConfig — one dataclass describes every architecture in the zoo.

Families:
  dense   — standard decoder (GQA/MQA attention + gated MLP)
  moe     — dense attention + mixture-of-experts MLP
  mla     — multi-head latent attention (MiniCPM3 / DeepSeek-style)
  ssm     — attention-free Mamba-2 (SSD) stack
  hybrid  — parallel attention + SSM heads per block (Hymba)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | mla | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int

    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    window: Optional[int] = None      # sliding-window size (SWA) or None
    rope_theta: float = 10_000.0

    # ---- mlp ----
    d_ff: int = 0
    act: str = "swiglu"               # swiglu | geglu

    # ---- moe ----
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ---- mla (minicpm3 / deepseek style) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- ssm (mamba2 / SSD) ----
    d_inner: int = 0
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssm_chunk: int = 128

    # ---- embeddings ----
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma: scale embeddings by sqrt(d)

    # ---- norm / numerics ----
    rms_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"
    score_dtype: str = "float32"    # attention score pipeline ("bfloat16"
                                    # halves the dominant HBM traffic; the
                                    # m/l softmax stats stay f32)

    # ---- modality frontend stub ----
    frontend: Optional[str] = None    # "vision" | "audio" | None
    n_prefix_embeds: int = 0          # patch/frame embeddings fed directly

    # ---- runtime knobs (not architecture) ----
    use_pallas: bool = False
    q_block: int = 512
    kv_block: int = 512
    remat: str = "nothing"            # nothing | dots | none
    attn_impl: str = "auto"           # auto | blockwise | banded

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- derived
    @property
    def q_dim(self) -> int:
        if self.family == "mla":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "mla", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (bounded cache)."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.window is not None) or (
            self.window is not None)

    def cache_len(self, seq_len: int) -> int:
        """Allocated KV-cache length for a given context length."""
        if self.window is not None:
            return min(self.window, seq_len)
        return seq_len

    # ------------------------------------------------------------- counts
    def param_count(self) -> int:
        """Exact parameter count (matches init_params)."""
        d, V = self.d_model, self.vocab
        total = V * d                         # input embedding
        if not self.tie_embeddings:
            total += d * V                    # lm head
        total += d                            # final norm
        per_layer = 0
        if self.family in ("dense", "moe", "hybrid"):
            per_layer += 2 * d                # attn norm + mlp norm
            if self.family == "hybrid":
                per_layer += 2 * d            # fusion norms
        if self.family == "mla":
            per_layer += 2 * d
        if self.has_attention and self.family != "mla":
            per_layer += d * self.q_dim + 2 * d * self.kv_dim \
                + self.q_dim * d
        if self.family == "mla":
            qr, kr = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vh = self.qk_nope_dim, self.qk_rope_dim, self.v_head_dim
            H = self.n_heads
            per_layer += d * qr + qr + qr * H * (nope + rope)      # q path
            per_layer += d * (kr + rope) + kr                      # kv compress
            per_layer += kr * H * (nope + vh)                      # kv expand
            per_layer += H * vh * d                                # out proj
        if self.has_ssm:
            di, N, G, Hs = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            conv_ch = di + 2 * G * N
            per_layer += d * (2 * di + 2 * G * N + Hs)             # in_proj
            per_layer += conv_ch * self.conv_kernel + conv_ch      # conv
            per_layer += Hs * 3                                    # A_log, D, dt_bias
            per_layer += di                                        # gated norm
            per_layer += di * d                                    # out_proj
            if self.family == "ssm":
                per_layer += d                                     # block norm
        if self.is_moe:
            per_layer += d * self.n_experts                        # router
            per_layer += self.n_experts * 3 * d * self.d_ff_expert
        elif self.family in ("dense", "mla", "hybrid"):
            per_layer += 3 * d * self.d_ff
        return total + self.n_layers * per_layer

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) \
            * 3 * self.d_model * self.d_ff_expert
        return self.param_count() - inactive
