"""Top-level model: embedding, scan-over-layers stack, loss, prefill, decode.

Parameters are layer-stacked (every block leaf gets a leading ``n_layers``
dim) and applied with ``lax.scan`` so the HLO stays O(1) in depth — critical
for 62-layer models compiled for 512 SPMD devices. Rematerialization policy
is applied to the scanned block body.

The LM head / CE loss is computed *chunked over the sequence* so the full
(B, S, V) logits tensor is never materialized (vocab up to 256k).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .blocks import apply_block, decode_block, init_block, init_layer_cache
from .config import ModelConfig
from .layers import dense, rms_norm, trunc_normal

LOSS_CHUNK = 512


# ------------------------------------------------------------------- params
def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    Vp = padded_vocab(cfg)
    params = {
        "embed": trunc_normal(k_embed, (Vp, cfg.d_model), 1.0, dt),
        "blocks": jax.vmap(lambda k: init_block(cfg, k))(
            jax.random.split(k_blocks, cfg.n_layers)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = trunc_normal(k_head, (cfg.d_model, Vp),
                                         cfg.d_model ** -0.5, dt)
    return params


def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    """Vocab padded for clean TP sharding (standard MaxText-style trick);
    logits for padding ids are masked to -inf in the loss."""
    return -(-cfg.vocab // multiple) * multiple


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Abstract (ShapeDtypeStruct) params — used by the dry-run."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ------------------------------------------------------------------ helpers
def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if cfg.remat == "outputs":
        # save only the post-collective block outputs: backward never
        # re-executes the forward TP psums / SP gathers
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "block_out"))
    return jax.checkpoint(fn)      # "nothing": save only block boundaries


def embed_tokens(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0) \
        .astype(jnp.dtype(cfg.compute_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        # modality stub: precomputed patch/frame embeddings occupy the first
        # n_prefix positions (assignment: frontend is a stub).
        P = prefix_embeds.shape[1]
        x = jnp.concatenate(
            [prefix_embeds.astype(x.dtype), x[:, P:]], axis=1)
    return x


def backbone(cfg: ModelConfig, params: Dict, x: jax.Array,
             positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Apply all blocks. Returns (hidden, aux_loss_sum)."""

    def layer(carry, p):
        h, aux = carry
        h, a, _ = apply_block(cfg, p, h, positions)
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(cfg, layer),
                               (x, jnp.float32(0.0)), params["blocks"])
    return rms_norm(x, params["final_norm"], cfg.rms_eps), aux


def lm_head_weight(cfg: ModelConfig, params: Dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ------------------------------------------------------------------- train
def token_loss(cfg: ModelConfig, params: Dict, hidden: jax.Array,
               labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Chunked cross-entropy: never materializes (B, S, V) logits."""
    B, S, d = hidden.shape
    W = lm_head_weight(cfg, params)
    Vp = W.shape[-1]
    chunk = min(LOSS_CHUNK, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    hc = hidden.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, chunk).transpose(1, 0, 2)
    vocab_ok = jnp.arange(Vp) < cfg.vocab        # mask padded vocab ids

    @jax.checkpoint       # logits are recomputed in backward, never stored
    def chunk_loss(carry, inp):
        h, l, m = inp
        logits = dense(h, W).astype(jnp.dtype(cfg.logit_dtype))
        logits = constrain(logits, "logits_chunk")
        logits = jnp.where(vocab_ok[None, None], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        ce = (lse - picked) * m
        return (carry[0] + ce.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk_loss, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens (B,S) int32, labels (B,S) int32, mask (B,S) f32,
    optional prefix_embeds (B,P,d)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = embed_tokens(cfg, params, tokens, batch.get("prefix_embeds"))
    hidden, aux = backbone(cfg, params, x, positions)
    ce = token_loss(cfg, params, hidden, batch["labels"], batch["mask"])
    loss = ce + cfg.router_aux_coef * aux / max(cfg.n_layers, 1)
    return loss, {"ce": ce, "aux": aux}


# ------------------------------------------------------------------- serve
def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None
            ) -> Tuple[Dict, jax.Array]:
    """Full-sequence forward that also builds the decode cache.

    Returns (cache pytree stacked over layers, last-position logits (B, V)).
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = embed_tokens(cfg, params, tokens, prefix_embeds)

    def layer(carry, p):
        h, aux = carry
        h, a, cache = apply_block(cfg, p, h, positions, collect_cache=True)
        return (h, aux + a), cache

    (x, _), caches = jax.lax.scan(_remat(cfg, layer),
                                  (x, jnp.float32(0.0)), params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = dense(x[:, -1], lm_head_weight(cfg, params)) \
        .astype(jnp.dtype(cfg.logit_dtype))
    return caches, logits[:, :cfg.vocab] if padded_vocab(cfg) != cfg.vocab \
        else logits


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Zeroed decode cache stacked over layers."""
    one = init_layer_cache(cfg, batch, cfg.cache_len(cache_len))
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def decode_step(cfg: ModelConfig, params: Dict, cache, tokens: jax.Array,
                pos: jax.Array) -> Tuple[Any, jax.Array]:
    """One decode step. tokens: (B,) int32; pos: scalar int32 (the position
    being generated, whose K/V enter the cache). Returns (cache', logits)."""
    x = jnp.take(params["embed"], tokens, axis=0) \
        .astype(jnp.dtype(cfg.compute_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    def layer(x_t, inp):
        p, c = inp
        c, x_t = decode_block(cfg, p, c, x_t, pos)
        return x_t, c

    x, new_cache = jax.lax.scan(layer, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = dense(x, lm_head_weight(cfg, params)) \
        .astype(jnp.dtype(cfg.logit_dtype))
    return new_cache, logits
