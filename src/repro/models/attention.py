"""Attention — memory-bounded pure-jnp implementations.

Three paths, all numerically equivalent to naive softmax attention (the
oracle in tests and in kernels/flash_attention/ref.py):

* ``attention_blockwise`` — lax.scan over KV blocks with online softmax:
  O(S²) FLOPs (causal-masked half is wasted — the TPU Pallas flash kernel
  skips it; the waste shows up honestly in the roofline "useful flops"
  ratio), O(S·block) memory.
* ``attention_banded`` — for sliding-window attention: lax.scan over *query*
  blocks, each attending to a fixed-size (window + q_block) KV slice via
  dynamic_slice — O(S·W) FLOPs, wasteless up to block rounding.
* ``attention_decode`` — single-query attention over a cache (optionally a
  ring buffer for SWA).

All operate on (B, S, H, D) layouts with GQA grouping handled by reshaping
q to (B, KVH, G, S, D).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_heads(q, n_kv: int):
    """(B, S, Hq, D) -> (B, KVH, G, S, D)"""
    B, S, Hq, D = q.shape
    G = Hq // n_kv
    return q.reshape(B, S, n_kv, G, D).transpose(0, 2, 3, 1, 4)


def _merge_heads(x):
    """(B, KVH, G, S, D) -> (B, S, Hq, D)"""
    B, KVH, G, S, D = x.shape
    return x.transpose(0, 3, 1, 2, 4).reshape(B, S, KVH * G, D)


def attention_blockwise(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        kv_block: int = 512,
                        scale: Optional[float] = None,
                        score_dtype=jnp.float32) -> jax.Array:
    """q: (B, S, Hq, Dk); k: (B, S, KVH, Dk); v: (B, S, KVH, Dv)."""
    B, S, Hq, Dk = q.shape
    KVH = k.shape[2]
    Dv = v.shape[3]
    scale = scale if scale is not None else Dk ** -0.5
    score_dtype = jnp.dtype(score_dtype)
    kv_block = min(kv_block, S)
    while S % kv_block:
        kv_block //= 2
    nb = S // kv_block

    qh = _split_heads(q * jnp.asarray(scale, q.dtype), KVH)   # (B,KVH,G,S,Dk)
    kh = k.transpose(0, 2, 1, 3)                              # (B,KVH,S,Dk)
    vh = v.transpose(0, 2, 1, 3)                              # (B,KVH,S,Dv)
    q_pos = jnp.arange(S)
    G = Hq // KVH

    @jax.checkpoint     # backward recomputes the block scores (flash-style)
    def body(carry, j):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kh, j * kv_block, kv_block, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vh, j * kv_block, kv_block, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kb,
                       preferred_element_type=jnp.float32) \
            .astype(score_dtype)
        kv_pos = j * kv_block + jnp.arange(kv_block)
        mask = jnp.ones((S, kv_block), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        s = jnp.where(mask[None, None, None], s,
                      jnp.asarray(NEG_INF, score_dtype))
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        p = jnp.exp(s - m_new[..., None].astype(score_dtype))
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.astype(jnp.float32).sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, S), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return _merge_heads(out).astype(q.dtype)


def attention_banded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     window: int, q_block: int = 512,
                     scale: Optional[float] = None,
                     score_dtype=jnp.float32) -> jax.Array:
    """Sliding-window causal attention, O(S·window).

    Scans over query blocks; each block attends to a fixed-size KV slice
    [start, start + window + q_block) where start = max(0, blk_end - W - QB),
    clamped so the slice is static-shaped (dynamic_slice clamps at edges and
    masking fixes up the overlap).
    """
    B, S, Hq, Dk = q.shape
    KVH = k.shape[2]
    Dv = v.shape[3]
    scale = scale if scale is not None else Dk ** -0.5
    q_block = min(q_block, S)
    while S % q_block:
        q_block //= 2
    nqb = S // q_block
    span = min(S, window + q_block)

    qh = _split_heads(q * jnp.asarray(scale, q.dtype), KVH)   # (B,KVH,G,S,D)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    G = Hq // KVH

    @jax.checkpoint     # backward recomputes banded scores per q block
    def body(_, i):
        q0 = i * q_block
        qb = jax.lax.dynamic_slice_in_dim(qh, q0, q_block, axis=3)
        start = jnp.maximum(q0 + q_block - span, 0)
        kb = jax.lax.dynamic_slice_in_dim(kh, start, span, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vh, start, span, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                       preferred_element_type=jnp.float32) \
            .astype(jnp.dtype(score_dtype))
        q_pos = q0 + jnp.arange(q_block)
        kv_pos = start + jnp.arange(span)
        mask = (q_pos[:, None] >= kv_pos[None, :]) & \
               (q_pos[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[None, None, None], s,
                      jnp.asarray(NEG_INF, jnp.dtype(score_dtype)))
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.astype(jnp.float32).sum(axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bhkd->bhgqd",
                       (p.astype(jnp.float32) / jnp.maximum(l, 1e-30)
                        ).astype(vb.dtype), vb,
                       preferred_element_type=jnp.float32)
        return None, o

    _, outs = jax.lax.scan(body, None, jnp.arange(nqb))
    # outs: (nqb, B, KVH, G, q_block, Dv) -> (B, KVH, G, S, Dv)
    outs = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KVH, G, S, Dv)
    return _merge_heads(outs).astype(q.dtype)


def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_positions: jax.Array, pos: jax.Array, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token decode attention.

    q: (B, 1, Hq, Dk); caches: (B, C, KVH, D); cache_positions: (C,) the
    absolute position stored in each cache slot (ring-aware); pos: scalar —
    the current token's position (its K/V must already be in the cache).
    """
    B, _, Hq, Dk = q.shape
    KVH = k_cache.shape[2]
    scale = scale if scale is not None else Dk ** -0.5
    qh = _split_heads(q * jnp.asarray(scale, q.dtype), KVH)   # (B,KVH,G,1,D)
    kh = k_cache.transpose(0, 2, 1, 3)                        # (B,KVH,C,D)
    vh = v_cache.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, kh,
                   preferred_element_type=jnp.float32)
    valid = cache_positions <= pos
    if window is not None:
        valid &= pos - cache_positions < window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vh.dtype), vh,
                   preferred_element_type=jnp.float32)
    return _merge_heads(o).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=None, impl="auto",
              kv_block=512, q_block=512, scale=None,
              score_dtype=jnp.float32):
    """Dispatcher used by model blocks (self-attention, S_q == S_kv)."""
    if impl == "auto":
        impl = "banded" if (window is not None and window < q.shape[1]) \
            else "blockwise"
    if impl == "banded":
        assert window is not None
        return attention_banded(q, k, v, window=window, q_block=q_block,
                                scale=scale, score_dtype=score_dtype)
    return attention_blockwise(q, k, v, causal=causal, window=window,
                               kv_block=kv_block, scale=scale,
                               score_dtype=score_dtype)


def attention_reference(q, k, v, *, causal=True, window=None, scale=None):
    """Naive O(S²)-memory oracle (tests only — small shapes)."""
    B, S, Hq, Dk = q.shape
    KVH = k.shape[2]
    scale = scale if scale is not None else Dk ** -0.5
    qh = _split_heads(q * jnp.asarray(scale, q.dtype), KVH)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, k.transpose(0, 2, 1, 3),
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype),
                   v.transpose(0, 2, 1, 3),
                   preferred_element_type=jnp.float32)
    return _merge_heads(o).astype(q.dtype)
