"""Flash attention forward — Pallas TPU kernel.

TPU adaptation (not a CUDA port): the grid is (B, Hq, nQ, nK) with the KV
dimension innermost and SEQUENTIAL ("arbitrary" semantics) so the online
softmax accumulators (m, l, acc) live in VMEM scratch across KV steps; the
MXU sees (q_block x D) @ (D x kv_block) matmuls with both dims multiples of
128 (q_block/kv_block default 512/512, D >= 64). HBM->VMEM movement is
expressed with BlockSpecs: each grid step stages exactly one q block and
one kv block; Pallas double-buffers the streams automatically.

Causal skipping: blocks strictly above the diagonal are masked (their loads
still stream; the TPU cost model makes skipping loads via scalar prefetch a
second-order win at these block sizes — documented in DESIGN.md).

GQA is native: the q-head grid index maps to kv head h // G in the BlockSpec
index_map, so KV is never repeated in memory.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
               scale: float, causal: bool, window: Optional[int],
               q_block: int, kv_block: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (qb, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (kb, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (kb, Dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (qb, kb)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    s.shape, 0)
    kv_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                      s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos >= kv_pos
    if window is not None:
        mask &= q_pos - kv_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]                                   # (qb,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(l_sc[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None,
                        q_block: int = 512, kv_block: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, KVH, S, D). Returns (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    KVH = k.shape[1]
    Dv = v.shape[-1]
    G = Hq // KVH
    scale = scale if scale is not None else D ** -0.5
    q_block = min(q_block, S)
    while S % q_block:
        q_block //= 2
    kv_block = min(kv_block, S)
    while S % kv_block:
        kv_block //= 2
    nq, nk = S // q_block, S // kv_block

    grid = (B, Hq, nq, nk)
    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, n_kv=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_block, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, kv_block, Dv),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, Dv), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
