"""Jitted public wrapper for the flash attention kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import flash_attention_fwd
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    q_block: int = 512, kv_block: int = 512,
                    interpret: bool = False):
    """q: (B, Hq, S, D); k/v: (B, KVH, S, D) -> (B, Hq, S, D).

    TPU-target Pallas kernel; pass interpret=True to execute the kernel
    body in Python on CPU (how CI validates it against the oracle).
    """
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               scale=scale, q_block=q_block,
                               kv_block=kv_block, interpret=interpret)


reference = flash_attention_ref
