"""Pure-jnp oracle for the flash attention kernel (GQA, causal, SWA)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, KVH, S, D) -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    KVH = k.shape[1]
    G = Hq // KVH
    scale = scale if scale is not None else D ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window is not None:
        mask &= pos[:, None] - pos[None, :] < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)
