"""Jitted wrappers: array / pytree -> per-chunk fingerprints via the Pallas
kernel.

Reuses core.fingerprint's lane conversion and chunk geometry so chunk
boundaries and bit patterns match the store exactly. ``fingerprint`` is the
one-tensor path; ``fingerprint_tree`` fingerprints a whole flat payload
dict in a single fused dispatch (pack + tiled kernel in one jit) — see
core.fingerprint.fingerprint_tree_packed for the packing scheme.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core.fingerprint import (_to_u32_lanes, chunk_geometry,
                                 fingerprint_tree_packed)
from .kernel import fingerprint_lanes


@functools.partial(jax.jit, static_argnames=("chunk_bytes", "interpret",
                                             "tile_lanes"))
def fingerprint(arr: jax.Array, chunk_bytes: int = 1 << 20, *,
                tile_lanes: Optional[int] = None,
                interpret: bool = False) -> jax.Array:
    n_chunks, lanes_per_chunk = chunk_geometry(
        tuple(arr.shape), str(arr.dtype), chunk_bytes)
    u = _to_u32_lanes(arr)
    pad = n_chunks * lanes_per_chunk - u.size
    u = jnp.pad(u, (0, pad)).reshape(n_chunks, lanes_per_chunk)
    return fingerprint_lanes(u, tile_lanes=tile_lanes, interpret=interpret)


def fingerprint_tree(tree, chunk_bytes: int = 1 << 20, *,
                     interpret: bool = False,
                     stats: Optional[dict] = None) -> Dict[str, np.ndarray]:
    """Whole-checkpoint fingerprints through the Pallas kernel: ONE device
    dispatch, one (total_chunks, 2) D2H transfer."""
    return fingerprint_tree_packed(tree, chunk_bytes, backend="pallas",
                                   interpret=interpret, stats=stats)
