"""Jitted wrapper: array -> per-chunk fingerprints via the Pallas kernel.

Reuses core.fingerprint's lane conversion so chunk boundaries and bit
patterns match the store exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.fingerprint import _to_u32_lanes
from .kernel import fingerprint_lanes


@functools.partial(jax.jit, static_argnames=("chunk_bytes", "interpret"))
def fingerprint(arr: jax.Array, chunk_bytes: int = 1 << 20, *,
                interpret: bool = False) -> jax.Array:
    itemsize = jnp.dtype(arr.dtype).itemsize
    if arr.dtype == jnp.bool_:
        itemsize = 1
    elems_per_chunk = max(1, chunk_bytes // itemsize)
    n = arr.size
    n_chunks = max(1, -(-n // elems_per_chunk))
    u = _to_u32_lanes(arr)
    lanes_per_chunk = (elems_per_chunk * u.size) // max(n, 1) if n else 1
    pad = n_chunks * lanes_per_chunk - u.size
    u = jnp.pad(u, (0, pad)).reshape(n_chunks, lanes_per_chunk)
    return fingerprint_lanes(u, interpret=interpret)
