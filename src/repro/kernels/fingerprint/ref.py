"""Oracle: the numpy fingerprint from core (one source of truth)."""
from ...core.fingerprint import fingerprint_chunks_ref

__all__ = ["fingerprint_chunks_ref"]
