"""Chunk fingerprint — Pallas TPU kernel (the paper's C1 on-device).

Computes the 64-bit multiply-xor fingerprint of every checkpoint chunk at
HBM bandwidth. The grid is 2-D: ``(n_chunks, n_tiles)`` — each chunk row is
streamed through VMEM in ``tile_lanes``-wide inner tiles rather than one
whole-chunk block, so

* chunks larger than VMEM work (the old one-block-per-chunk layout capped
  chunk_bytes at the VMEM size), and
* the Mosaic pipeline double-buffers tile fetches while the VPU mixes the
  previous tile.

Both reductions (xor, wraparound add) are associative, so the tile dimension
uses ``"arbitrary"`` semantics and accumulates partial results into the
output block across tiles; the chunk dimension stays ``"parallel"``.

A per-row ``widths`` operand masks lanes past each row's true lane count —
this is what lets ``core.fingerprint.fingerprint_tree_packed`` pack tensors
of different dtypes (different lanes-per-chunk) into one padded buffer and
fingerprint an entire checkpoint in a single dispatch. The (n_chunks, 2)
table (8 B per chunk) is all that crosses the host link; only changed chunks
are then fetched and SHA-256'd by the store (core/diff).

Matches core.fingerprint bit-for-bit (same constants, same mix).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import compiler_params

_C1 = 0x9E3779B9
_C2 = 0x85EBCA6B
_C3 = 0xC2B2AE35

# Default inner tile: 64Ki lanes = 256 KiB of VMEM per buffer — small enough
# to double-buffer comfortably, large enough to amortize grid overhead.
DEFAULT_TILE_LANES = 1 << 16


def _fp_kernel(w_ref, u_ref, out_ref):
    j = pl.program_id(1)
    tile = u_ref.shape[1]
    c1, c2, c3 = (jnp.uint32(_C1), jnp.uint32(_C2), jnp.uint32(_C3))
    u = u_ref[...]                                    # (1, tile) uint32
    pos_i = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1) + j * tile
    pos = pos_i.astype(jnp.uint32)
    mixed = (u * c1) ^ (pos * c2 + c3)
    mixed = mixed ^ (mixed >> jnp.uint32(15))
    mixed = mixed * c3
    # Mask lanes past this row's true width (ragged rows in a packed buffer
    # and column padding up to n_tiles*tile): zero is the identity of both
    # reductions, so masked lanes contribute nothing.
    mixed = jnp.where(pos_i < w_ref[0, 0], mixed, jnp.uint32(0))
    part_xor = jax.lax.reduce(mixed, jnp.uint32(0), jax.lax.bitwise_xor,
                              dimensions=(0, 1))
    part_sum = jnp.sum(mixed, dtype=jnp.uint32)

    @pl.when(j == 0)
    def _init():
        out = jnp.stack([part_xor, part_sum]).astype(jnp.uint32)
        out_ref[0] = jax.lax.bitcast_convert_type(out, jnp.int32)

    @pl.when(j != 0)
    def _accumulate():
        prev = jax.lax.bitcast_convert_type(out_ref[0], jnp.uint32)
        out = jnp.stack([prev[0] ^ part_xor, prev[1] + part_sum])
        out_ref[0] = jax.lax.bitcast_convert_type(
            out.astype(jnp.uint32), jnp.int32)


def fingerprint_lanes(u32_lanes: jax.Array, *,
                      widths: jax.Array | None = None,
                      tile_lanes: int | None = None,
                      interpret: bool = False) -> jax.Array:
    """(n_chunks, lanes) uint32 [+ per-row widths] -> (n_chunks, 2) int32.

    ``widths`` (n_chunks,) int32 gives each row's true lane count; lanes at
    positions >= width are masked out of the reduction. Defaults to the full
    buffer width (the single-tensor case, where every row is dense).
    """
    n_chunks, lanes = u32_lanes.shape
    tile = min(lanes, tile_lanes or DEFAULT_TILE_LANES)
    n_tiles = -(-lanes // tile)
    col_pad = n_tiles * tile - lanes
    if col_pad:
        u32_lanes = jnp.pad(u32_lanes, ((0, 0), (0, col_pad)))
    if widths is None:
        w = jnp.full((n_chunks, 1), lanes, jnp.int32)
    else:
        w = widths.astype(jnp.int32).reshape(n_chunks, 1)
    return pl.pallas_call(
        _fp_kernel,
        grid=(n_chunks, n_tiles),
        in_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 2), jnp.int32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(w, u32_lanes)
