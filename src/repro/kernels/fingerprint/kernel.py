"""Chunk fingerprint — Pallas TPU kernel (the paper's C1 on-device).

Computes the 64-bit multiply-xor fingerprint of every checkpoint chunk at
HBM bandwidth: grid (n_chunks,), each step streams one chunk's uint32 lanes
into VMEM, mixes them on the VPU (elementwise multiply/xor/shift — no MXU),
and reduces to 2 int32 words. The (n_chunks, 2) table (16 B per MiB chunk)
is all that crosses the host link; only changed chunks are then fetched and
SHA-256'd by the store (core/diff.diff_layer_fingerprint).

Matches core.fingerprint bit-for-bit (same constants, same mix).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_C1 = 0x9E3779B9
_C2 = 0x85EBCA6B
_C3 = 0xC2B2AE35


def _fp_kernel(u_ref, out_ref):
    u = u_ref[0]                                     # (lanes,) uint32
    lanes = u.shape[0]
    c1, c2, c3 = (jnp.uint32(_C1), jnp.uint32(_C2), jnp.uint32(_C3))
    pos = jax.lax.broadcasted_iota(jnp.uint32, (lanes,), 0)
    mixed = (u * c1) ^ (pos * c2 + c3)
    mixed = mixed ^ (mixed >> jnp.uint32(15))
    mixed = mixed * c3
    fp_xor = jax.lax.reduce(mixed, jnp.uint32(0), jax.lax.bitwise_xor,
                            dimensions=(0,))
    fp_sum = jnp.sum(mixed, dtype=jnp.uint32)
    out = jnp.stack([fp_xor, fp_sum]).astype(jnp.uint32)
    out_ref[0] = jax.lax.bitcast_convert_type(out, jnp.int32)


def fingerprint_lanes(u32_lanes: jax.Array, *, interpret: bool = False
                      ) -> jax.Array:
    """u32_lanes: (n_chunks, lanes_per_chunk) uint32 -> (n_chunks, 2) i32."""
    n_chunks, lanes = u32_lanes.shape
    return pl.pallas_call(
        _fp_kernel,
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((1, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 2), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(u32_lanes)
