"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

Grid (B, H, n_chunks); the chunk dimension is innermost and SEQUENTIAL
("arbitrary" semantics) so the (P, N) state lives in VMEM scratch across
chunk steps — the cross-chunk recurrence never touches HBM. Per grid step
the MXU computes three small matmuls (C·Bᵀ (QxQ), scores·x (QxP),
state update (NxQ)@(QxP)); Q=chunk and P,N are 64..128 — MXU-aligned.

Inputs are the post-conv activations in (B, S, H|G, ·) layout; BlockSpecs
slice one chunk per step and map the head index onto its B/C group
(GQA-style grouping native, no expansion in memory).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import compiler_params


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,
                y_ref, hout_ref, h_sc, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    x = x_ref[0, :, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    Bc = b_ref[0, :, 0].astype(jnp.float32)         # (Q, N)
    Cc = c_ref[0, :, 0].astype(jnp.float32)         # (Q, N)
    A = a_ref[0, 0]                                 # scalar
    D = d_ref[0, 0]

    da = dt * A                                     # (Q,)
    L = jnp.cumsum(da)                              # (Q,)
    # intra-chunk quadratic form
    cb = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(L[:, None] - L[None, :]), 0.0)
    scores = cb * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)
    # inter-chunk: incoming state
    h = h_sc[...]                                   # (P, N)
    y += jax.lax.dot_general(Cc * jnp.exp(L)[:, None], h,
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # skip connection
    y += x * D
    y_ref[0, :, 0] = y.astype(y_ref.dtype)
    # state update: h' = exp(L_Q) h + x^T (B * exp(L_Q - L) dt)
    w = jnp.exp(L[-1] - L) * dt                     # (Q,)
    h_new = jnp.exp(L[-1]) * h + jax.lax.dot_general(
        x, Bc * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (P, N)
    h_sc[...] = h_new

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
             Cc: jax.Array, D: jax.Array, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) f32 (post-softplus); A: (H,) f32 (negative);
    Bc/Cc: (B,S,G,N); D: (H,). Returns (y (B,S,H,P), h (B,H,P,N) f32)."""
    B, S, H, P = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    A2 = A.reshape(H, 1).astype(jnp.float32)
    D2 = D.reshape(H, 1).astype(jnp.float32)

    kern = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, h = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, i: (b, i, h)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, i, rep=rep: (b, i, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, i, rep=rep: (b, i, h // rep, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i: (h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt.astype(jnp.float32), Bc, Cc, A2, D2)
    return y, h
