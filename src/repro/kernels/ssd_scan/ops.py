"""Jitted public wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan
from .ref import ssd_reference


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bc, Cc, D, *, chunk: int = 128, interpret: bool = False):
    return ssd_scan(x, dt, A, Bc, Cc, D, chunk=chunk, interpret=interpret)


reference = ssd_reference
