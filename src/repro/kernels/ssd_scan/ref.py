"""Oracle for the SSD scan kernel: the exact sequential recurrence
(shared with models.ssm — one source of truth for the math)."""
from ...models.ssm import ssd_chunked as ssd_chunked_ref
from ...models.ssm import ssd_reference

__all__ = ["ssd_reference", "ssd_chunked_ref"]
