"""Version compatibility for the Pallas TPU API.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` in older JAX
releases (e.g. 0.4.x). Kernels build their compiler params through this
shim so they run on whichever name the installed JAX exposes; if neither
exists (or the kwargs don't apply), the kernel runs with compiler defaults
rather than failing at import/call time.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(**kwargs):
    """-> a pltpu CompilerParams instance, or None if unavailable."""
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    try:
        return cls(**kwargs)
    except TypeError:
        return None
