"""Serving launcher: load a layered image (with cross-variant dedup) and
serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
        --store /tmp/ckpt --batch 4 --prompt-len 16 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import CheckpointManager, CheckpointPolicy
from ..configs import get_config, get_smoke_config
from ..models import init_params
from ..serve import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--store", default=None,
                    help="layered checkpoint store to load weights from")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.store:
        mgr = CheckpointManager(args.store, cfg.name,
                                CheckpointPolicy(async_write=False))
        out = mgr.restore()
        if out is None:
            raise SystemExit(f"no checkpoint in {args.store}")
        params = jax.tree.map(jnp.asarray, out[0])
        print(f"[serve] loaded step-{out[2]} from layered store")
    else:
        params = init_params(cfg, jax.random.PRNGKey(0))

    eng = Engine(cfg, params,
                 max_len=args.prompt_len + args.steps + 8)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab))
    t0 = time.perf_counter()
    res = eng.generate(prompts, steps=args.steps,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    toks = res.tokens.size
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    print("[serve] first sequences:", res.tokens[:2, :8].tolist())


if __name__ == "__main__":
    main()
