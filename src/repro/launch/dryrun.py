import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the jitted
step (the SAME object the trainer/server runs) is lowered with
ShapeDtypeStruct inputs, compiled for the production mesh, and its
memory_analysis / cost_analysis / collective schedule are recorded for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
    python -m repro.launch.dryrun --all --jobs 4      # subprocess per cell

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init. Smoke tests / benches never import this module.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import List, Optional, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def model_flops_for(cfg, sp) -> float:
    """MODEL_FLOPS: 6·N·D train (3 matmul passes), 2·N·D forward-only.
    MoE: active params only."""
    n = cfg.active_param_count()
    if sp.kind == "train":
        return 6.0 * n * sp.global_batch * sp.seq_len
    if sp.kind == "prefill":
        return 2.0 * n * sp.global_batch * sp.seq_len
    return 2.0 * n * sp.global_batch          # decode: one token


def run_cell(arch: str, shape: str, mesh_name: str,
             recipe_override: Optional[str] = None,
             extra: Optional[dict] = None,
             grad_reduce_dtype: Optional[str] = None,
             microbatches: int = 0) -> dict:
    import jax
    from ..configs import SHAPES, get_config, input_specs
    from ..roofline import analyze_compiled
    from ..train import TrainConfig, make_decode_step, make_prefill_step, \
        make_train_step
    from .mesh import make_production_mesh, mesh_context

    cfg = get_config(arch)
    if extra:
        cfg = cfg.replace(**{k: v for k, v in extra.items()
                             if hasattr(cfg, k)})
    sp = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    specs = input_specs(cfg, shape)
    t0 = time.perf_counter()

    with mesh_context(mesh):
        if sp.kind == "train":
            tcfg = TrainConfig(recipe=recipe_override,
                               grad_reduce_dtype=grad_reduce_dtype,
                               microbatches=microbatches)
            bundle = make_train_step(cfg, tcfg,
                                     mesh, sp.global_batch, sp.seq_len)
            import jax.numpy as jnp
            from ..models import init_params
            from ..optim import init_opt_state
            pshape = jax.eval_shape(
                lambda: init_params(cfg, jax.random.PRNGKey(0)))
            oshape = jax.eval_shape(lambda: init_opt_state(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshape)))
            lowered = bundle.fn.lower(pshape, oshape, specs)
        elif sp.kind == "prefill":
            bundle = make_prefill_step(cfg, mesh, sp.global_batch,
                                       sp.seq_len, recipe_name=recipe_override)
            pshape = bundle.abstract_inputs[0]
            args = [pshape, specs["tokens"]]
            if cfg.n_prefix_embeds:
                args.append(specs["prefix_embeds"])
            lowered = bundle.fn.lower(*args)
        else:  # decode
            bundle = make_decode_step(cfg, mesh, sp.global_batch,
                                      sp.seq_len, recipe_name=recipe_override)
            pshape = bundle.abstract_inputs[0]
            lowered = bundle.fn.lower(pshape, specs["cache"],
                                      specs["tokens"], specs["pos"])
        compiled = lowered.compile()

    dt = time.perf_counter() - t0
    res = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name,
        recipe=(recipe_override or bundle.recipe.name),
        model_flops=model_flops_for(cfg, sp),
        n_devices=mesh.devices.size, compile_seconds=dt)
    print(compiled.memory_analysis())
    d = res.to_json()
    d["ok"] = True
    return d


def cells(mesh_sel: str) -> List[Tuple[str, str, str]]:
    from ..configs import ARCH_IDS, applicable_shapes, get_config
    meshes = {"pod": ["pod"], "multipod": ["multipod"],
              "both": ["pod", "multipod"]}[mesh_sel]
    out = []
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            for m in meshes:
                out.append((arch, shape, m))
    return out


def result_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{mesh_name}{suffix}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--recipe", default=None)
    ap.add_argument("--tag", default="", help="result filename suffix")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (ints only)")
    ap.add_argument("--grad-reduce-dtype", default=None)
    ap.add_argument("--microbatches", type=int, default=0)
    args = ap.parse_args()

    extra = {}
    for kv in args.set:
        k, v = kv.split("=")
        extra[k] = int(v) if v.lstrip("-").isdigit() else v
    if args.recipe:
        # the recipe name is part of the experiment identity
        pass

    if not args.all:
        assert args.arch and args.shape
        meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
        for m in meshes:
            path = result_path(args.arch, args.shape, m, args.tag)
            try:
                d = run_cell(args.arch, args.shape, m, args.recipe, extra,
                             grad_reduce_dtype=args.grad_reduce_dtype,
                             microbatches=args.microbatches)
            except Exception as e:
                d = {"arch": args.arch, "shape": args.shape, "mesh": m,
                     "ok": False, "error": f"{type(e).__name__}: {e}",
                     "trace": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(d, f, indent=1, default=str)
            status = "OK" if d.get("ok") else f"FAIL ({d.get('error')})"
            print(f"[dryrun] {args.arch} x {args.shape} x {m}: {status}")
        return 0

    # --all: one subprocess per cell (isolation + bounded memory)
    todo = cells(args.mesh)
    failures = []
    for arch, shape, m in todo:
        path = result_path(arch, shape, m, args.tag)
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                if json.load(f).get("ok"):
                    print(f"[dryrun] {arch} x {shape} x {m}: cached")
                    continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", m]
        if args.recipe:
            cmd += ["--recipe", args.recipe]
        if args.tag:
            cmd += ["--tag", args.tag]
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            print(r.stdout[-1500:], r.stderr[-1500:])
            failures.append((arch, shape, m))
        else:
            print(r.stdout.strip().splitlines()[-1])
    print(f"[dryrun] done: {len(todo) - len(failures)}/{len(todo)} OK")
    for f3 in failures:
        print("  FAILED:", f3)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
