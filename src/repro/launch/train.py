"""Training launcher: --arch <id> end-to-end driver.

Wires together the full production stack: mesh, sharded train step,
deterministic data pipeline, incremental (code-injection) checkpointing,
watchdog + restart-resume. On this CPU container it is exercised with
reduced configs (examples/quickstart.py); on a real slice the same file
runs the full configs — nothing here is CPU-specific.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \\
        --steps 50 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import CheckpointManager, CheckpointPolicy
from ..configs import get_config, get_smoke_config
from ..data import SyntheticTokens, make_global_batch
from ..ft import Watchdog
from ..models import init_params
from ..optim import AdamWConfig, init_opt_state
from ..train import TrainConfig, make_train_step
from .mesh import make_mesh, mesh_context


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--incremental", action="store_true", default=True)
    ap.add_argument("--full-ckpt", dest="incremental", action="store_false")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--watchdog-s", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=args.lr,
                                         decay_steps=max(args.steps, 10)))

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    start_step = 0
    mgr = None
    if args.ckpt:
        mgr = CheckpointManager(
            args.ckpt, cfg.name,
            CheckpointPolicy(every_steps=args.ckpt_every,
                             incremental=args.incremental,
                             async_write=True))
        restored = mgr.restore()
        if restored is not None:
            p_np, o_np, start_step = restored
            params = jax.tree.map(jnp.asarray, p_np)
            opt = jax.tree.map(jnp.asarray, o_np)
            print(f"[train] resumed from step {start_step}")

    ds = SyntheticTokens(cfg.vocab, batch=args.batch, seq=args.seq)
    with mesh_context(mesh):
        bundle = make_train_step(cfg, tcfg, mesh, args.batch, args.seq)
        wd = Watchdog(args.watchdog_s, lambda: print("[watchdog] step hung")) \
            if args.watchdog_s > 0 else None
        t0 = time.perf_counter()
        for s in range(start_step, args.steps):
            host_batch = ds.batch_at(s)
            batch = make_global_batch(
                mesh, {k: v for k, v in
                       zip(("tokens", "labels", "mask"),
                           (bundle.in_shardings[2]["tokens"].spec,
                            bundle.in_shardings[2]["labels"].spec,
                            bundle.in_shardings[2]["mask"].spec))},
                host_batch)
            if wd:
                wd.arm()
            params, opt, metrics = bundle.fn(params, opt, batch)
            if wd:
                wd.disarm()
            if (s + 1) % max(1, args.steps // 20) == 0 or s == start_step:
                print(f"[train] step {s + 1}: loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if mgr and (s + 1) % args.ckpt_every == 0:
                mgr.save(s + 1, jax.tree.map(np.asarray, params),
                         jax.tree.map(np.asarray, opt))
        if mgr:
            mgr.wait()
        dt = time.perf_counter() - t0
        n_steps = args.steps - start_step
        print(f"[train] {n_steps} steps in {dt:.1f}s "
              f"({dt / max(n_steps, 1) * 1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
