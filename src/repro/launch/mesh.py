"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod slice).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis carries only data parallelism + the inter-pod gradient all-reduce
(DCN-friendly: one collective per step crosses pods).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run forces 512 host devices *before* first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Elastic-scaling entry: any (data, model) factorization of the
    currently-alive device set (see ft/elastic.py)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
