"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod slice).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis carries only data parallelism + the inter-pod gradient all-reduce
(DCN-friendly: one collective per step crosses pods).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run forces 512 host devices *before* first init).

Compat: ``jax.sharding.AxisType`` / ``jax.set_mesh`` only exist on newer
jax. On older releases (e.g. 0.4.x, the oldest CI cell) ``make_mesh`` drops
the axis_types kwarg (Auto is the implicit behavior there) and
``mesh_context`` falls back to the Mesh object itself, which is a context
manager with the equivalent scoping semantics for everything this repo does
(shard_map / with_sharding_constraint / NamedSharding-jit).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def mesh_context(mesh):
    """``with mesh_context(mesh):`` — jax.set_mesh where available, the
    Mesh-as-context-manager fallback otherwise."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Elastic-scaling entry: any (data, model) factorization of the
    currently-alive device set (see ft/elastic.py)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))
