"""AdamW with f32 master weights — production mixed-precision setup.

Params live in bf16 (compute); the optimizer carries an f32 master copy and
f32 moments. With ZeRO-1 the master/m/v trees are sharded over the DP axes
(see sharding.rules.opt_specs) so their memory is amortized across replicas.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    # copy=True: an f32 param leaf must NOT alias its master (both trees
    # are donated by the train step; aliased buffers break donation)
    f32 = lambda t: jax.tree.map(
        lambda a: jnp.array(a, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return {"step": jnp.zeros((), jnp.int32), "master": f32(params),
            "m": zeros(params), "v": zeros(params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def apply_update(cfg: AdamWConfig, params, opt_state, grads
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(master, m, v, g):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps) +
                                    cfg.weight_decay * master)
        return new_master, m, v

    flat_m, treedef = jax.tree.flatten(opt_state["master"])
    flat_mm = jax.tree.leaves(opt_state["m"])
    flat_vv = jax.tree.leaves(opt_state["v"])
    flat_g = jax.tree.leaves(grads)
    outs = [upd(a, b, c, g) for a, b, c, g in
            zip(flat_m, flat_mm, flat_vv, flat_g)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                              new_master, params)
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
