from .adamw import AdamWConfig, apply_update, global_norm, init_opt_state, lr_at
from .compression import (compressed_psum, dequantize_int8,
                          init_error_feedback, quantize_int8)

__all__ = ["AdamWConfig", "apply_update", "global_norm", "init_opt_state",
           "lr_at", "compressed_psum", "dequantize_int8",
           "init_error_feedback", "quantize_int8"]
