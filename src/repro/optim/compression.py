"""Gradient compression: int8 block-quantized all-reduce with error feedback.

At 1000-node scale the DP gradient all-reduce is the dominant inter-pod
collective. This module halves its bytes (bf16 -> int8 + f32 scale per
2048-block) with error feedback, so quantization error is carried into the
next step instead of lost (Seide et al. / 1-bit Adam lineage).

Scheme (exact-summable): every replica quantizes against a SHARED per-block
scale (pmax of local scales — one tiny f32 collective), so the int8
payloads psum exactly in int32; the result is rescaled once. Error feedback
is computed against the actually-transmitted value.

``compressed_psum`` must run inside shard_map with the DP axes mapped; the
roofline collective term measures the byte reduction from the lowered HLO.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


def _axis_size(name):
    """jax.lax.axis_size where it exists; psum(1) on older jax (0.4.x) —
    the counting psum constant-folds at trace time inside shard_map."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


def _blocks(x: jax.Array) -> jax.Array:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)


def _unblocks(b: jax.Array, shape, dtype) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return b.reshape(-1)[:n].reshape(shape).astype(dtype)


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 blocks (nb, BLOCK), f32 scales (nb,))."""
    blk = _blocks(g)
    scale = jnp.max(jnp.abs(blk), axis=1) / 127.0
    q = jnp.clip(jnp.round(blk / jnp.maximum(scale, 1e-12)[:, None]),
                 -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape,
                    dtype=jnp.float32) -> jax.Array:
    return _unblocks(q.astype(jnp.float32) * scale[:, None], shape, dtype)


def compressed_psum(g: jax.Array, err: jax.Array, axis_names
                    ) -> Tuple[jax.Array, jax.Array]:
    """Mean-all-reduce of ``g`` over mapped ``axis_names`` with int8 payload.

    Returns (mean grad f32 (g.shape), new error feedback (g.shape))."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    replicas = 1
    for a in axis_names:
        replicas *= _axis_size(a)

    target = _blocks(g) + _blocks(err)
    local_scale = jnp.max(jnp.abs(target), axis=1) / 127.0
    shared_scale = jax.lax.pmax(local_scale, axis_names)        # tiny f32
    q = jnp.clip(jnp.round(target /
                           jnp.maximum(shared_scale, 1e-12)[:, None]),
                 -127, 127).astype(jnp.int8)                    # int8 payload
    sent = q.astype(jnp.float32) * shared_scale[:, None]
    new_err = _unblocks(target - sent, g.shape, jnp.float32)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_names)         # exact
    mean = _unblocks(acc.astype(jnp.float32) * shared_scale[:, None]
                     / replicas, g.shape, jnp.float32)
    return mean, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
