"""Straggler mitigation: deadline-based contribution skipping.

At thousands of nodes the step time is max over hosts; a single slow host
(thermal throttle, page cache miss, flaky NIC) sets the pace. Standard
mitigations: (a) skip the straggler's microbatch contribution for the step
(gradient from N-1 replicas is an unbiased estimate), (b) alert + cordon
hosts that straggle persistently.

``DeadlineSkipper`` implements the control logic host-side (policy, EWMA of
step times, per-host offender tracking). The *mechanism* for (a) in SPMD is
a masked gradient: each host contributes ``weight in {0,1}`` and the psum
divides by the sum of weights — expressed in the train step as the loss
mask, so no collective topology changes. Tests simulate slow hosts and
assert skip/cordon decisions; the weighting math is exercised in
tests/test_ft.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class StragglerStats:
    steps: int = 0
    skips: int = 0
    cordoned: List[int] = field(default_factory=list)


class DeadlineSkipper:
    """EWMA deadline policy: a host whose step exceeds
    ``factor * ewma`` is skipped this step; ``cordon_after`` consecutive
    skips flags it for replacement (elastic shrink)."""

    def __init__(self, n_hosts: int, factor: float = 2.0,
                 cordon_after: int = 3, ewma_alpha: float = 0.1):
        self.n_hosts = n_hosts
        self.factor = factor
        self.cordon_after = cordon_after
        self.alpha = ewma_alpha
        self.ewma: Optional[float] = None
        self.consecutive: Dict[int, int] = {h: 0 for h in range(n_hosts)}
        self.stats = StragglerStats()

    def decide(self, host_step_seconds: Dict[int, float]) -> Dict[int, bool]:
        """-> {host: include_in_step}. Updates cordon state."""
        healthy = sorted(host_step_seconds.values())
        median = healthy[len(healthy) // 2]
        if self.ewma is None:
            self.ewma = median
        deadline = self.factor * self.ewma
        include: Dict[int, bool] = {}
        for h, t in host_step_seconds.items():
            ok = t <= deadline
            include[h] = ok
            if ok:
                self.consecutive[h] = 0
            else:
                self.consecutive[h] += 1
                self.stats.skips += 1
                if self.consecutive[h] >= self.cordon_after and \
                        h not in self.stats.cordoned:
                    self.stats.cordoned.append(h)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * median
        self.stats.steps += 1
        return include

    def contribution_weights(self, include: Dict[int, bool]) -> Dict[int, float]:
        n_in = sum(include.values()) or 1
        return {h: (self.n_hosts / n_in if ok else 0.0)
                for h, ok in include.items()}
