"""Background scrub — the detection half of the self-healing loop.

The paper's injection method bypasses Docker's checksum pipeline, which
makes content-addressed integrity the *only* line between a fast rebuild
and silently serving corrupt weights. PR 6 hardened the *in-flight* path
(every wire byte re-hashed on receipt); this module closes the *at-rest*
gap: bit-rot, torn writes that slipped past orphan adoption, a bad disk
on one relay tier.

``LayerStore.scrub()`` (core/store.py) performs the walk; this module owns
the structured result model and the persisted cursor so the walk is

* **incremental** — ``max_bytes``/``max_items`` budgets bound one slice,
* **resumable** — the cursor (``<root>/scrub.cursor.json``) records the
  next blob shard, so a fleet-scale store is scrubbed across many slices
  without ever re-hashing a shard twice per pass,
* **complete** — metadata (layer checksums, config locks, chain re-key
  links) is re-verified at the start of every pass; the 256 blob shards
  are re-hashed against their content addresses across the slices.

A ``ScrubReport`` separates *corruption* (``corrupt_blob``,
``missing_blob``, ``layer_*``, ``chain_mismatch`` — anything that breaks
a committed image) from *debris* (``orphan_blob``/``orphan_layer`` — an
unreferenced leftover of a crashed push: ugly, never load-bearing).
``repair_image`` (core/registry.py) consumes the corruption findings and
heals them from any peer holding a good copy.

CLI::

    PYTHONPATH=src python -m repro.ft.scrub --root /path/to/store
    PYTHONPATH=src python -m repro.ft.scrub --soak [--slice-bytes N]

``--soak`` is the scheduled-CI entry: it builds a multitenant store (a
base image plus tenant fine-tunes replicated across stores, the
BENCH_multitenant topology in miniature), scrubs every store full-pass
AND sliced, fails on any finding, then proves the detector against
itself — seeded at-rest bit-flips (``ft.faults.inject_bitrot``) must be
detected 100% with exact attribution.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Iterable, List, Optional

#: blob payloads shard under ``blobs/sha256/<h[:2]>/`` — 256 buckets; the
#: scrub cursor is the index of the next un-scrubbed shard of this pass.
N_SHARDS = 256

CURSOR_FILE = "scrub.cursor.json"

#: finding kinds that break a committed image (repair_image's input);
#: everything else ("orphan_*") is crash debris awaiting gc.
CORRUPTION_KINDS = (
    "corrupt_blob", "missing_blob", "layer_checksum_mismatch",
    "layer_unreadable", "missing_layer", "config_lock_mismatch",
    "chain_mismatch", "manifest_unreadable",
)


@dataclass
class ScrubFinding:
    """One integrity problem, attributed as precisely as the walk can.

    ``kind`` is one of ``CORRUPTION_KINDS`` or ``orphan_blob`` /
    ``orphan_layer``. ``image``/``tag``/``layer_id`` locate the first
    committed reference the walk found (empty for orphans — nothing
    committed reaches them). ``blob`` is the chunk's content address when
    the finding is blob-scoped.
    """

    kind: str
    detail: str = ""
    image: str = ""
    tag: str = ""
    layer_id: str = ""
    blob: str = ""

    @property
    def is_corruption(self) -> bool:
        return self.kind in CORRUPTION_KINDS


@dataclass
class ScrubReport:
    """Structured result of one scrub slice (or a full pass).

    ``complete`` is True when this slice finished the pass: every blob
    shard has been re-hashed since the cursor was last reset and the
    metadata walk ran clean start-to-end. Counters cover THIS slice only;
    findings likewise — callers accumulating a sliced pass union them.
    """

    findings: List[ScrubFinding] = field(default_factory=list)
    blobs_scanned: int = 0
    bytes_scanned: int = 0
    layers_scanned: int = 0
    images_scanned: int = 0
    shards_scanned: int = 0
    complete: bool = False
    next_shard: int = 0          # cursor after this slice (0 = pass done)
    wall_s: float = 0.0

    @property
    def corruptions(self) -> List[ScrubFinding]:
        """Findings that break a committed image — repair_image's input."""
        return [f for f in self.findings if f.is_corruption]

    @property
    def orphans(self) -> List[ScrubFinding]:
        return [f for f in self.findings if not f.is_corruption]

    @property
    def corrupt_blob_hashes(self) -> List[str]:
        """Content addresses of committed blobs that failed re-hash or
        vanished — deduplicated, sorted."""
        return sorted({f.blob for f in self.findings
                       if f.kind in ("corrupt_blob", "missing_blob")
                       and f.blob})

    @property
    def clean(self) -> bool:
        return not self.findings

    def merge(self, other: "ScrubReport") -> None:
        """Accumulate a later slice of the same pass into this report."""
        self.findings.extend(other.findings)
        self.blobs_scanned += other.blobs_scanned
        self.bytes_scanned += other.bytes_scanned
        self.layers_scanned += other.layers_scanned
        self.images_scanned += other.images_scanned
        self.shards_scanned += other.shards_scanned
        self.complete = other.complete
        self.next_shard = other.next_shard
        self.wall_s += other.wall_s

    def summary(self) -> str:
        state = "complete" if self.complete else \
            f"paused@shard={self.next_shard}"
        return (f"scrub {state}: {self.blobs_scanned} blobs "
                f"({self.bytes_scanned} B) / {self.layers_scanned} layers "
                f"/ {self.images_scanned} images, "
                f"{len(self.corruptions)} corruptions, "
                f"{len(self.orphans)} orphans")


# ------------------------------------------------------------------ cursor
def cursor_path(root: str) -> str:
    return os.path.join(root, CURSOR_FILE)


def load_cursor(root: str) -> int:
    """Next shard of the in-progress pass (0 = start a fresh pass). A
    missing or unreadable cursor restarts the pass — over-scrubbing is
    always safe."""
    try:
        with open(cursor_path(root), "rb") as f:
            shard = int(json.load(f).get("next_shard", 0))
    except (OSError, ValueError):
        return 0
    return shard if 0 <= shard < N_SHARDS else 0


def save_cursor(root: str, next_shard: int) -> None:
    """Persist the pass position (atomic rename; no fsync — losing the
    cursor only costs re-scrubbed shards, never correctness)."""
    tmp = f"{cursor_path(root)}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(json.dumps({"next_shard": int(next_shard)}).encode())
    os.replace(tmp, cursor_path(root))


def clear_cursor(root: str) -> None:
    try:
        os.remove(cursor_path(root))
    except OSError:
        pass


# --------------------------------------------------------------- CLI / soak
def _build_soak_store(base_dir: str, tenants: int = 3):
    """A miniature of the BENCH_multitenant topology: one base image plus
    ``tenants`` fine-tunes sharing its blob universe, consolidated onto a
    remote store — the namespace the scheduled scrub-soak walks."""
    import numpy as np

    from ..core import LayerStore, push_delta
    from ..core.manifest import Instruction

    rng = np.random.default_rng(7)
    src = LayerStore(os.path.join(base_dir, "src"), chunk_bytes=4096)
    backbone = {f"b{i}": rng.standard_normal(2048).astype(np.float32)
                for i in range(6)}
    ins = [Instruction("FROM", "scratch", "config"),
           Instruction("COPY", "backbone", "content"),
           Instruction("CMD", "serve", "config")]
    src.build_image("base", "v1", ins,
                    {"backbone": lambda: backbone})
    for t in range(tenants):
        adapter = dict(backbone)
        adapter[f"b{t % 6}"] = backbone[f"b{t % 6}"] + float(t + 1)
        src.build_image(f"tenant-{t}", "v1", ins,
                        {"backbone": lambda a=adapter: a})
    remote = LayerStore(os.path.join(base_dir, "remote"), chunk_bytes=4096)
    push_delta(src, remote, "base", "v1")
    for t in range(tenants):
        push_delta(src, remote, f"tenant-{t}", "v1")
    return src, remote


def _soak(slice_bytes: Optional[int],
          seeds: Optional[Iterable[int]] = None) -> int:
    import shutil
    import tempfile

    from ..core import LayerStore
    from .faults import inject_bitrot

    base = tempfile.mkdtemp(prefix="scrub_soak_")
    try:
        src, remote = _build_soak_store(base)
        failures = 0
        for store in (src, remote):
            # full pass in one slice
            rep = store.scrub()
            print(f"{store.root}: {rep.summary()}")
            if not (rep.complete and rep.clean):
                failures += 1
            # the same pass sliced under a byte budget must find the same
            # nothing and terminate (a complete pass resets the cursor)
            sliced = ScrubReport()
            for _ in range(N_SHARDS + 4):
                part = store.scrub(max_bytes=slice_bytes or 64 << 10)
                sliced.merge(part)
                if part.complete:
                    break
            print(f"{store.root}: sliced -> {sliced.summary()}")
            if not (sliced.complete and sliced.clean):
                failures += 1
        # detector self-proof: seeded at-rest flips must be found, all of
        # them, on a scratch copy of the remote — one round per seed (CI
        # shards the seed range exactly like the chaos soak)
        for seed in (seeds if seeds is not None else [11]):
            victim_root = os.path.join(base, f"victim-{seed}")
            shutil.copytree(remote.root, victim_root)
            victim = LayerStore(victim_root, chunk_bytes=4096)
            flips = inject_bitrot(victim_root, seed=seed, count=3)
            rep = victim.scrub()
            detected = set(rep.corrupt_blob_hashes)
            want = {h for h, _ in flips}
            print(f"bitrot self-proof (seed {seed}): injected {len(want)}, "
                  f"detected {len(detected & want)}")
            if detected & want != want:
                failures += 1
            shutil.rmtree(victim_root, ignore_errors=True)
        if failures:
            print(f"FAIL: {failures} scrub-soak failures")
            return 1
        print("scrub-soak: all stores clean, detector catches 100% of "
              "seeded bit-rot")
        return 0
    finally:
        shutil.rmtree(base, ignore_errors=True)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="scrub a LayerStore (or run the CI scrub-soak)")
    ap.add_argument("--root", help="store root to scrub")
    ap.add_argument("--soak", action="store_true",
                    help="build the multitenant soak store and scrub it, "
                         "failing on any finding")
    ap.add_argument("--slice-bytes", type=int, default=None,
                    help="re-hash budget per slice (default: one pass)")
    ap.add_argument("--seeds", default=None,
                    help="bitrot self-proof seeds for --soak: 'N', "
                         "'A:B', 'A:B:S', or the CI shard shorthand "
                         "'I::S' (see ft.chaos.parse_seeds)")
    ap.add_argument("--reset", action="store_true",
                    help="discard the persisted cursor first")
    args = ap.parse_args(argv)

    if args.soak:
        from .chaos import parse_seeds
        return _soak(args.slice_bytes,
                     seeds=None if args.seeds is None
                     else parse_seeds(args.seeds))
    if not args.root:
        ap.error("--root or --soak required")
    from ..core import LayerStore

    store = LayerStore(args.root)
    if args.reset:
        clear_cursor(args.root)
    total = ScrubReport()
    while True:
        rep = store.scrub(max_bytes=args.slice_bytes)
        total.merge(rep)
        if rep.complete or args.slice_bytes is None:
            break
    print(total.summary())
    for f in total.findings:
        where = ":".join(p for p in (f.image, f.tag, f.layer_id[:12])
                         if p)
        print(f"  {f.kind:24s} {where} {f.blob[:12]} {f.detail}")
    return 1 if total.findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
