"""Deterministic fault injection — the chaos-testing substrate.

Production code is threaded with **named fault points** (see the table in
README "Failure modes & recovery"): zero-cost no-ops until a
``FaultInjector`` is installed, at which point any of them can drop,
corrupt, delay or crash — the four failure modes a replication topology
must survive. The replication stack (``core.store.LayerStore``,
``core.registry.DeltaReceiver``/``RelayNode``/``replicate_fanout``,
``serve.CheckpointFollower``) calls ``fault_point(name, key=..., data=...)``
at every seam; the chaos harness (``ft.chaos``) and the regression tests
drive seeded fault matrices through them and assert convergence.

Determinism is the whole point: whether a given hit fires is a pure
function of ``(seed, point, key, nth-hit-of-that-key)`` — a SHA-256-derived
uniform draw, NOT a sequential RNG — so the decision is reproducible even
when hits arrive on pool threads in nondeterministic order. A failing chaos
seed printed by CI replays bit-identically on a laptop.

Fault points currently wired (point / key):

    store.write_blob      <store.root>:<blob hash>   (disk-write corruption)
    store.read_blob       <store.root>:<blob hash>   (bad-sector read)
    store.commit          <store.root>               (death at the rename)
    wire.negotiate        <dst.root>                 (lost exchange)
    wire.probe_blobs      <dst.root>
    wire.receive_layer    <dst.root>:<layer id>
    wire.receive_blob     <dst.root>:<blob hash>     (corrupt transfer)
    wire.commit           <dst.root>                 (death pre-rename)
    relay.fan             <relay.root>               (relay dies at re-fan)
    follower.pull         <local.root>:<image>:<tag> (hung/failed poll)
    bundle.publish        <registry root>:<image>:<from>-><to>  and
                          <registry root>:<image>:index
                          (passive-registry write: torn/corrupt bundle
                          file, stale or corrupt index)
    bundle.fetch          same keys as bundle.publish
                          (passive-registry read: truncated bundle,
                          unreachable index)

``FaultInjected`` subclasses ``ConnectionError`` so a dropped wire op looks
exactly like a flaky network to the caller; ``CrashInjected`` simulates
process death — the run aborts mid-flight and the next attempt plays the
part of the restarted process (crash-atomicity means it converges).

The fifth mode, ``bitrot``, models silent at-rest corruption (a decaying
disk, not a flaky wire). At a fault point it behaves like ``corrupt`` —
the crucial difference is WHERE it is aimed: fired at ``store.write_blob``
the flipped byte is *persisted*, committing a corrupt blob that no
in-flight check will ever re-read (the torn-write-that-slipped-past-
adoption case). For corruption of blobs already at rest there is
``inject_bitrot(root, seed, ...)``, which flips one deterministic byte in
each selected committed blob file in place. Detection is
``LayerStore.scrub`` (ft/scrub.py); healing is ``repair_image``
(core/registry.py); the chaos matrix (ft/chaos.py) soaks every
(bitrot × scenario) cell to bit-identical deep-verified convergence.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class FaultInjected(ConnectionError):
    """A dropped operation (transient, retryable — like a reset socket)."""


class CrashInjected(RuntimeError):
    """Simulated process death at a fault point. Handlers treat it like
    SIGKILL: whatever was in flight is abandoned (possibly torn, never
    committed) and a fresh attempt must converge from the debris."""


@dataclass
class FaultSpec:
    """One rule of a fault plan.

    ``point`` names the fault point exactly, or a prefix ending in ``*``
    (``"wire.*"``). ``match`` is a substring the hit's key must contain —
    target one store by its root path, one blob by its hash. ``prob`` is
    the per-hit fire probability (decided deterministically, see module
    docstring). ``skip`` lets the first N matching hits of each key pass
    untouched; ``times`` caps fires per key (None = every time). Counters
    are per ``(spec, point, key)`` so concurrency cannot reorder them.
    """

    point: str
    mode: str         # "drop" | "corrupt" | "delay" | "crash" | "bitrot"
    prob: float = 1.0
    match: str = ""
    skip: int = 0
    times: Optional[int] = 1
    delay_s: float = 0.01

    def __post_init__(self):
        if self.mode not in ("drop", "corrupt", "delay", "crash",
                             "bitrot"):
            raise ValueError(f"unknown fault mode {self.mode!r}")

    def matches(self, point: str, key: str) -> bool:
        if self.point.endswith("*"):
            if not point.startswith(self.point[:-1]):
                return False
        elif point != self.point:
            return False
        return self.match in key


@dataclass
class FaultEvent:
    """One fired fault, recorded for assertions."""

    point: str
    key: str
    mode: str
    hit: int                        # nth hit of (point, key) when it fired


def _unit(seed: int, point: str, key: str, n: int) -> float:
    """Deterministic uniform [0, 1) from the hit's identity — stable under
    any thread interleaving (no shared RNG stream)."""
    h = hashlib.sha256(f"{seed}:{point}:{key}:{n}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class FaultInjector:
    """A seeded fault plan. Install with ``with injector.active():`` (or
    the module-level ``inject(...)`` convenience); every ``fault_point``
    call in the process consults it while installed. Thread-safe: hit
    counters and the event log are lock-guarded, fire decisions are
    order-independent (hash-based)."""

    def __init__(self, seed: int = 0, specs: Tuple[FaultSpec, ...] = ()):
        self.seed = seed
        self.specs: List[FaultSpec] = list(specs)
        self.log: List[FaultEvent] = []
        self._hits: Dict[Tuple[int, str, str], int] = {}
        self._lock = threading.Lock()

    def fired(self, point: Optional[str] = None) -> int:
        with self._lock:
            return sum(1 for e in self.log
                       if point is None or e.point == point)

    def hit(self, point: str, key: str, data: Optional[bytes]
            ) -> Optional[bytes]:
        """Evaluate one fault-point hit. First matching spec that fires
        wins. Returns (possibly corrupted) ``data``; raises on drop/crash;
        sleeps on delay."""
        for si, spec in enumerate(self.specs):
            if not spec.matches(point, key):
                continue
            with self._lock:
                n = self._hits.get((si, point, key), 0)
                self._hits[(si, point, key)] = n + 1
            if n < spec.skip:
                continue
            fires_before = n - spec.skip
            if spec.times is not None and fires_before >= spec.times:
                continue
            if spec.prob < 1.0 and \
                    _unit(self.seed, point, key, n) >= spec.prob:
                continue
            with self._lock:
                self.log.append(FaultEvent(point, key, spec.mode, n))
            if spec.mode == "drop":
                raise FaultInjected(
                    f"injected drop at {point} ({key[-24:]})")
            if spec.mode == "crash":
                raise CrashInjected(
                    f"injected crash at {point} ({key[-24:]})")
            if spec.mode == "delay":
                time.sleep(spec.delay_s)
                return data
            # corrupt/bitrot: flip one deterministic byte; at a data-less
            # point a corruption manifests as a drop (nothing to mangle).
            # The two modes differ only in aim (see module docstring):
            # "bitrot" targets write/at-rest points so the flip PERSISTS.
            if data is None or len(data) == 0:
                raise FaultInjected(
                    f"injected corrupt-drop at {point} ({key[-24:]})")
            pos = int(_unit(self.seed, point, key, n) * len(data)) \
                % len(data)
            out = bytearray(data)
            out[pos] ^= 0xFF
            return bytes(out)
        return data

    @contextlib.contextmanager
    def active(self):
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError("a FaultInjector is already installed")
            _ACTIVE = self
        try:
            yield self
        finally:
            with _INSTALL_LOCK:
                _ACTIVE = None


_ACTIVE: Optional[FaultInjector] = None
_INSTALL_LOCK = threading.Lock()


def fault_point(point: str, key: str = "",
                data: Optional[bytes] = None) -> Optional[bytes]:
    """The hook production code calls. A no-op (returns ``data``
    unchanged) unless an injector is installed — one attribute load on the
    hot path."""
    inj = _ACTIVE
    if inj is None:
        return data
    return inj.hit(point, key, data)


def inject(seed: int = 0, *specs: FaultSpec):
    """``with inject(seed, FaultSpec(...), ...) as inj:`` convenience."""
    return FaultInjector(seed, tuple(specs)).active()


def inject_bitrot(root: str, seed: int, count: int = 1,
                  candidates: Optional[List[str]] = None
                  ) -> List[Tuple[str, int]]:
    """Flip one byte in each of ``count`` at-rest blob payloads under
    ``<root>/blobs/sha256`` — the silent-disk-decay fault the scrub/repair
    loop must detect and heal.

    Victim selection and flip position are pure functions of
    ``(seed, blob hash)`` (the same SHA-derived draw as the fault points),
    so a chaos cell replays bit-identically. ``candidates`` restricts the
    victim pool to those hashes (e.g. one image's chunk set, so the cell
    knows which image to repair); default is every blob on disk. Flips are
    applied in place — no injector needs to be installed. Returns
    ``[(hash, flipped_offset), ...]`` for the detection assertions.
    """
    shard_root = os.path.join(root, "blobs", "sha256")
    if candidates is None:
        pool = []
        for sub in sorted(os.listdir(shard_root)):
            d = os.path.join(shard_root, sub)
            if os.path.isdir(d):
                pool.extend(sorted(os.listdir(d)))
    else:
        pool = sorted(set(candidates))
    pool = [h for h in pool
            if os.path.exists(os.path.join(shard_root, h[:2], h))]
    if not pool:
        return []
    ranked = sorted(pool, key=lambda h: _unit(seed, "bitrot.pick", h, 0))
    flipped: List[Tuple[str, int]] = []
    for h in ranked[:max(count, 0)]:
        path = os.path.join(shard_root, h[:2], h)
        size = os.path.getsize(path)
        if size == 0:
            continue
        pos = int(_unit(seed, "bitrot.pos", h, 0) * size) % size
        with open(path, "r+b") as f:
            f.seek(pos)
            byte = f.read(1)
            f.seek(pos)
            f.write(bytes([byte[0] ^ 0xFF]))
        flipped.append((h, pos))
    return flipped
