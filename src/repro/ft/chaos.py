"""Chaos harness: the seeded fault matrix CI soaks nightly.

Every cell of ``(drop | corrupt | delay | crash | bitrot) x (push | fanout
| relay | follower | bundle)`` runs one end-to-end replication under seeded faults
and asserts the topology converges **automatically** — no manual retry
call — to bit-identical committed replicas at every tier with zero torn
stores (``verify_image(deep=True)`` clean everywhere). The first four
modes strike in-flight (an installed ``FaultInjector`` at the wire/commit
seams); ``bitrot`` strikes at rest — seeded byte-flips in committed blobs
(``ft.faults.inject_bitrot``, plus a persisted ``store.write_blob`` flip
for the follower cell) that the scrub -> repair -> rollback loop must
detect 100%, heal from ANY peer (source, sibling replica, or a relay's
own CHILD), and re-verify deep-clean. Fire decisions are a pure function
of the seed (see ``ft.faults``), so any failing cell replays
bit-identically from the repro line it prints:

    PYTHONPATH=src python -m repro.ft.chaos --seeds 7 \\
        --scenarios relay --modes corrupt

Usage (tests import these; CI runs the CLI):

    from repro.ft.chaos import run_cell, run_matrix
    cell = run_cell("fanout", "crash", seed=3, base_dir=tmp)   # one cell
    cells = run_matrix(seeds=range(4))                         # full matrix
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import numpy as np

from .faults import CrashInjected, FaultSpec, inject, inject_bitrot
from .retry import RetryPolicy

MODES = ("drop", "corrupt", "delay", "crash", "bitrot")
SCENARIOS = ("push", "fanout", "relay", "follower", "bundle")

#: the nightly soak's seed range — CI shards it across a job matrix with
#: the ``I::S`` stride shorthand (see ``parse_seeds``)
SOAK_SEEDS = 16


def parse_seeds(spec: str):
    """Seed-spec grammar shared by the chaos and scrub CLIs: ``'N'`` (one
    seed), ``'A:B'`` (a range), ``'A:B:S'`` (a strided range), and the CI
    shard shorthand ``'I::S'`` — shard I of stride S over the nightly
    ``[0, SOAK_SEEDS)`` soak range, so 4 matrix jobs running ``0::4``
    .. ``3::4`` cover exactly the full range with no overlap."""
    if ":" not in spec:
        return [int(spec)]
    parts = spec.split(":")
    if len(parts) not in (2, 3):
        raise ValueError(f"bad seed spec {spec!r}")
    lo = int(parts[0]) if parts[0] else 0
    hi = int(parts[1]) if parts[1] else SOAK_SEEDS
    stride = int(parts[2]) if len(parts) == 3 and parts[2] else 1
    return range(lo, hi, stride)

# fast-converging policy: chaos cells only need *bounded* waits, the
# backoff-shape guarantees are hypothesis-proved in test_retry_property
_POLICY_KW = dict(max_attempts=4, base_delay_s=0.001, max_delay_s=0.02)


@dataclass
class ChaosCell:
    """Outcome of one matrix cell (also the failure record: ``error``
    carries the assertion + the repro line)."""

    scenario: str
    mode: str
    seed: int
    fired: int = 0                  # fault events the injector logged
    retries_spent: int = 0
    ok: bool = False
    error: str = ""

    @property
    def repro(self) -> str:
        return (f"PYTHONPATH=src python -m repro.ft.chaos "
                f"--seeds {self.seed} --scenarios {self.scenario} "
                f"--modes {self.mode}")


# --------------------------------------------------------------- fixtures
def _stores(base_dir: str, *names: str):
    from ..core import LayerStore
    return [LayerStore(str(Path(base_dir) / n), chunk_bytes=512)
            for n in names]


def _payloads(seed: int) -> Dict[str, Dict[str, np.ndarray]]:
    rng = np.random.default_rng(1000 + seed)
    return {"src": {"a": rng.standard_normal(1000).astype(np.float32),
                    "b": rng.standard_normal(500).astype(np.float32)},
            "deps": {"lib": rng.standard_normal(4000).astype(np.float32)}}


def _build_app(store, payloads) -> None:
    from ..core import Instruction
    ins = [Instruction("FROM", "base", "config"),
           Instruction("COPY", "src", "content"),
           Instruction("RUN", "deps", "content"),
           Instruction("CMD", "run", "config")]
    store.build_image("app", "v1", ins,
                      {k: (lambda v=v: v) for k, v in payloads.items()})


def _inject_v2(store, payloads) -> None:
    from ..core import inject_payload_update
    src2 = {k: v.copy() for k, v in payloads["src"].items()}
    src2["b"][3] = 42.0                     # ONE changed 512 B chunk
    inject_payload_update(store, "app", "v1", "v2", {"src": src2},
                          providers={"deps": lambda: payloads["deps"]})


def _snapshot(store, name: str, tag: str) -> dict:
    """Byte-exact image state — manifest, config, layer files, blobs."""
    manifest, config = store.read_image(name, tag)
    layers, blobs = {}, {}
    for lid in manifest.layer_ids:
        with open(store._layer_path(lid), "rb") as f:
            layers[lid] = f.read()
        for rec in store.read_layer(lid).records:
            for h in rec.chunks:
                blobs[h] = store.read_blob(h)
    return {"manifest": manifest.to_json(), "config": config.to_json(),
            "layers": layers, "blobs": blobs}


def _assert_converged(src, dsts, name: str, tag: str) -> None:
    want = _snapshot(src, name, tag)
    for d in dsts:
        problems = d.verify_image(name, tag, deep=True)
        assert problems == [], f"torn store {d.root}: {problems}"
        assert _snapshot(d, name, tag) == want, \
            f"replica {d.root} not bit-identical to source"


#: every in-flight protocol seam, with the side of the wire it strikes —
#: cells rotate through this table seed by seed, so the nightly soak
#: range ([0, 16)) hits each seam at least twice under every mode.  The
#: analyzer's R1 rule (repro.analysis) gates that every fault point in
#: src appears here or in a scenario's own specs: an uncovered point is
#: a dead kill-matrix cell.
SEAMS = (
    ("wire.negotiate", "dst"),
    ("wire.probe_blobs", "dst"),
    ("wire.receive_layer", "dst"),
    ("wire.receive_blob", "dst"),
    ("wire.commit", "dst"),
    ("store.read_blob", "src"),     # the SOURCE's disk read, mid-ship
    ("store.commit", "dst"),        # death/drop inside write_image
)


def _spec(mode: str, seed: int, dst_root: str,
          src_root: Optional[str] = None) -> FaultSpec:
    """The seam this cell strikes, rotated by seed. Topologies without a
    distinct source side (fan-out replicas share one source with the
    healthy majority) fall back to the canonical transfer seam so the
    fault stays scoped to the one sick replica."""
    point, side = SEAMS[seed % len(SEAMS)]
    if side == "src":
        if src_root is None:
            point = "wire.receive_blob"
        else:
            return FaultSpec(point=point, mode=mode, match=src_root)
    return FaultSpec(point=point, mode=mode, match=dst_root)


# ------------------------------------------------------- at-rest bitrot
def _chunkset(store, name: str, tag: str) -> list:
    """Every blob hash ``name:tag`` reaches — restricts the bitrot victim
    pool so the cell knows exactly which image to scrub and repair."""
    m, _ = store.read_image(name, tag)
    out = []
    for lid in m.layer_ids:
        for rec in store.read_layer(lid).records:
            out.extend(rec.chunks)
    return out


def _rot_and_heal(victim, name: str, tag: str, peers, seed: int,
                  count: int = 2) -> int:
    """The shared bitrot cell body: seeded at-rest flips on ``victim``,
    then the full self-healing loop — scrub must detect EXACTLY the
    flipped set (100% detection, no false positives), repair_image must
    restore it pulling only the damaged bytes from the given peers, and a
    re-scrub must run clean. Returns the number of flips (the cell's
    ``fired`` count)."""
    from ..core import repair_image
    flips = inject_bitrot(victim.root, seed, count=count,
                          candidates=_chunkset(victim, name, tag))
    assert flips, "bitrot found no victim blobs — fixture broken?"
    want = {h for h, _ in flips}
    rep = victim.scrub()
    assert set(rep.corrupt_blob_hashes) == want,         f"scrub detected {rep.corrupt_blob_hashes} != injected {sorted(want)}"
    # the healing path itself runs under fire: a dropped peer pull and a
    # simulated SIGKILL at the repair commit — a repair session must be
    # restartable from a (now stale) scrub report, re-verifying instead
    # of trusting it
    repair_specs = [FaultSpec(point="repair.pull", mode="drop",
                              match=victim.root, times=1),
                    FaultSpec(point="repair.commit", mode="crash",
                              match=victim.root, times=1)]
    rr = None
    with inject(seed, *repair_specs):
        for _ in range(4):
            try:
                rr = repair_image(victim, name, tag, peers=peers,
                                  scrub_report=rep)
                break
            except (ConnectionError, CrashInjected):
                continue            # the restarted repair session re-plans
    assert rr is not None and rr.verified_clean, \
        "repair did not deep-verify clean"
    assert rr.wire_amplification <= 1.25,         f"repair over-pulled: {rr.wire_amplification:.2f}x"
    victim.purge_quarantine()
    assert victim.scrub().clean, "re-scrub after repair found debris"
    return len(flips)


# -------------------------------------------------------------- scenarios
def _run_push(base_dir: str, mode: str, seed: int) -> tuple:
    from ..core import push_delta
    src, dst = _stores(base_dir, "src", "dst")
    payloads = _payloads(seed)
    _build_app(src, payloads)
    push_delta(src, dst, "app", "v1")               # warm base, no faults
    _inject_v2(src, payloads)
    if mode == "bitrot":
        push_delta(src, dst, "app", "v2")           # commit clean, rot at rest
        fired = _rot_and_heal(dst, "app", "v2", [src], seed)
        _assert_converged(src, [dst], "app", "v2")
        return fired, 0
    policy = RetryPolicy(seed=seed, **_POLICY_KW)
    with inject(seed, _spec(mode, seed, dst.root, src.root)) as inj:
        # in-run retries converge drops/corruption; a CrashInjected that
        # escapes is the PUSHER process dying (e.g. at its own disk read)
        # — the restarted pusher re-pushes, per kill-matrix semantics
        for _ in range(4):
            try:
                push_delta(src, dst, "app", "v2", retry=policy)
                break
            except CrashInjected:
                continue
    _assert_converged(src, [dst], "app", "v2")
    return inj.fired(), 0


def _run_fanout(base_dir: str, mode: str, seed: int) -> tuple:
    from ..core import replicate_fanout
    src, r0, r1, r2 = _stores(base_dir, "src", "r0", "r1", "r2")
    payloads = _payloads(seed)
    _build_app(src, payloads)
    replicate_fanout(src, [r0, r1, r2], "app", "v1")
    _inject_v2(src, payloads)
    policy = RetryPolicy(seed=seed, **_POLICY_KW)
    if mode == "bitrot":
        replicate_fanout(src, [r0, r1, r2], "app", "v2")
        # heal the rotten replica from a SIBLING, not the source —
        # any-peer anti-entropy across the fan
        fired = _rot_and_heal(r1, "app", "v2", [r0], seed)
        _assert_converged(src, [r0, r1, r2], "app", "v2")
        return fired, 0
    with inject(seed, _spec(mode, seed, r1.root)) as inj:  # one sick replica
        fan = replicate_fanout(src, [r0, r1, r2], "app", "v2",
                               retry=policy)
    assert fan.majority_ok, "healthy majority failed to commit"
    assert fan.n_ok == 3, \
        f"retry did not converge replica 1: {fan.replicas[1].error}"
    _assert_converged(src, [r0, r1, r2], "app", "v2")
    return inj.fired(), fan.retries_spent


def _run_relay(base_dir: str, mode: str, seed: int) -> tuple:
    from ..core import RelayNode, replicate_fanout
    src, mid, e0, e1 = _stores(base_dir, "src", "mid", "e0", "e1")
    payloads = _payloads(seed)
    _build_app(src, payloads)
    policy = RetryPolicy(seed=seed, **_POLICY_KW)
    relay = RelayNode(mid, children=[e0, e1], retry=policy)
    replicate_fanout(src, [relay], "app", "v1")
    _inject_v2(src, payloads)
    if mode == "bitrot":
        replicate_fanout(src, [relay], "app", "v2")
        # the MID tier rots and heals from its own CHILD — repair runs
        # the delta machinery in reverse, so direction doesn't matter
        fired = _rot_and_heal(mid, "app", "v2", [e1], seed)
        _assert_converged(src, [mid, e0, e1], "app", "v2")
        return fired, 0
    # the edge seam rotates; the relay's own fan point ALSO fires once —
    # the mid tier must survive its fan being dropped/killed and converge
    # through _retry_failed on the next fan attempt
    with inject(seed, _spec(mode, seed, e0.root),
                FaultSpec(point="relay.fan", mode=mode, match=mid.root,
                          times=1)) as inj:           # one sick edge
        fan = replicate_fanout(src, [relay], "app", "v2", retry=policy)
    rep = fan.replicas[0]
    assert rep.ok, f"relay tier failed: {rep.error}"
    assert rep.children is not None and rep.children.n_ok == 2, \
        "child retry did not converge the edge tier"
    _assert_converged(src, [mid, e0, e1], "app", "v2")
    assert not mid.leased("app", "v2"), \
        "converged children must have released their leases"
    return inj.fired(), fan.retries_spent + rep.children.retries_spent


def _run_follower(base_dir: str, mode: str, seed: int) -> tuple:
    # lazy: serve pulls in jax; the other scenarios stay numpy-only
    from ..core import Instruction, inject_payload_update
    from ..serve.engine import CheckpointFollower
    remote, local = _stores(base_dir, "remote", "local")
    rng = np.random.default_rng(2000 + seed)
    state = {"params/w": rng.standard_normal(1000).astype(np.float32),
             "opt/m": rng.standard_normal(500).astype(np.float32),
             "opt/__step__": np.asarray([1], np.int32)}
    ins = [Instruction("FROM", "arch", "config"),
           Instruction("COPY", "state", "content")]
    remote.build_image("ckpt", "step-00000001", ins,
                       {"state": lambda: state})
    policy = RetryPolicy(seed=seed, **_POLICY_KW)
    follower = CheckpointFollower(remote, local, keep=3, retry=policy)
    assert follower.poll().step == 1                 # warm base, no faults
    state2 = {k: v.copy() for k, v in state.items()}
    state2["params/w"][7] = 42.0
    state2["opt/__step__"][0] = 2
    inject_payload_update(remote, "ckpt", "step-00000001",
                          "step-00000002", {"state": state2})
    if mode == "bitrot":
        # a persisted write-path flip: the pull COMMITS a corrupt revision
        # (receive verified the wire bytes, the disk write rotted them) —
        # the follower's verify gate must catch it pre-swap and heal
        # in-line from the remote, within the same poll
        specs = [FaultSpec(point="store.write_blob", mode="bitrot",
                           match=local.root, times=1)]
    else:
        # the rotated seam plus the follower's own pull point: a poll
        # that dies (drop propagates out of _pull; CrashInjected is the
        # simulated SIGKILL) must be converged by the NEXT poll tick —
        # exactly how a supervised follower process behaves
        specs = [_spec(mode, seed, local.root, remote.root),
                 FaultSpec(point="follower.pull", mode=mode,
                           match=local.root, times=1)]
    with inject(seed, *specs) as inj:
        upd = None
        for _ in range(6):
            try:
                upd = follower.poll()
            except (ConnectionError, CrashInjected):
                continue            # the restarted follower re-polls
            if upd is not None and upd.step == 2:
                break
    assert upd is not None and upd.step == 2, "follower failed to advance"
    _assert_converged(remote, [local], "ckpt", "step-00000002")
    health = follower.health()
    assert health.consecutive_failures == 0 and health.last_success_step == 2
    if mode == "bitrot":
        assert health.corrupt_polls >= 1 and health.repairs >= 1,             "verify gate never engaged under write-path bitrot"
    return inj.fired(), health.retries_spent


def _run_bundle(base_dir: str, mode: str, seed: int) -> tuple:
    """The passive-registry chain under fire: the publisher writes bundles
    + a signed index through ``bundle.publish`` faults (torn bundle file,
    stale index, corrupt index), the follower plans and pulls through
    ``bundle.fetch`` faults (truncated/corrupt bundle, index/bundle hash
    mismatch, unreachable files). The contract: a corrupted advertisement
    is DETECTED at the edge (index signature, bundle sha) and the
    follower falls back — another published chain, or the smart remote
    pull — converging bit-identically; a crashed publisher leaves a
    stale-but-consistent index its restart converges."""
    from ..core import Instruction, PassiveRegistry, inject_payload_update
    from ..serve.engine import CheckpointFollower
    remote, local = _stores(base_dir, "remote", "local")
    reg = PassiveRegistry(str(Path(base_dir) / "registry"))
    rng = np.random.default_rng(3000 + seed)
    state = {"params/w": rng.standard_normal(1000).astype(np.float32),
             "opt/m": rng.standard_normal(500).astype(np.float32),
             "opt/__step__": np.asarray([1], np.int32)}
    ins = [Instruction("FROM", "arch", "config"),
           Instruction("COPY", "state", "content")]
    remote.build_image("ckpt", "step-00000001", ins,
                       {"state": lambda: state})
    policy = RetryPolicy(seed=seed, **_POLICY_KW)
    follower = CheckpointFollower(remote, local, keep=5, retry=policy,
                                  registry=reg)
    assert follower.poll().step == 1              # warm base, no faults
    prev_state = state
    for step in (2, 3):
        prev_state = {k: v.copy() for k, v in prev_state.items()}
        prev_state["params/w"][7] = float(step)
        prev_state["opt/__step__"][0] = step
        inject_payload_update(remote, "ckpt", f"step-{step - 1:08d}",
                              f"step-{step:08d}", {"state": prev_state})
    # a clean prior advertisement, so a faulted republish tests the
    # stale-index path (readers see THIS index until the new one lands)
    reg.publish_image(remote, "ckpt", "step-00000002",
                      from_tags=["step-00000001"])

    def publish_head():
        reg.publish_image(remote, "ckpt", "step-00000003",
                          from_tags=["step-00000001", "step-00000002"])

    if mode == "bitrot":
        publish_head()
        # at-rest rot in a published bundle file: the index still
        # advertises the clean hash, so the fetch MUST reject the bytes
        path = Path(reg.root) / "ckpt" / "bundles" / \
            "step-00000001__step-00000003.rdb"
        rotten = bytearray(path.read_bytes())
        rotten[len(rotten) // 2] ^= 0xFF
        path.write_bytes(bytes(rotten))
        fired = 1
        upd = follower.poll()
    else:
        specs = [FaultSpec(point="bundle.publish", mode=mode,
                           match=reg.root),
                 FaultSpec(point="bundle.fetch", mode=mode,
                           match=reg.root)]
        with inject(seed, *specs) as inj:
            # crash fires once PER FILE (spec counters are per key), so a
            # publisher that dies at bundle k restarts and dies at bundle
            # k+1 — bounded by the number of files, then it converges
            for _ in range(6):
                try:
                    publish_head()
                    break
                except CrashInjected:
                    continue        # the restarted publisher re-publishes
            upd = None
            for _ in range(6):
                try:
                    upd = follower.poll()
                except CrashInjected:
                    continue        # the restarted follower re-polls
                if upd is not None and upd.step == 3:
                    break
        fired = inj.fired()
    assert upd is not None and upd.step == 3, \
        "follower failed to reach head through the faulted registry"
    _assert_converged(remote, [local], "ckpt", "step-00000003")
    if mode == "bitrot":
        plan = follower.last_plan
        assert plan is not None and \
            (plan.edges_skipped >= 1 or plan.fallback == "remote"), \
            "rotten bundle was neither skipped nor fallen back from"
    return fired, follower.health().retries_spent


_RUNNERS = {"push": _run_push, "fanout": _run_fanout,
            "relay": _run_relay, "follower": _run_follower,
            "bundle": _run_bundle}


# ---------------------------------------------------------------- harness
def run_cell(scenario: str, mode: str, seed: int,
             base_dir: Optional[str] = None) -> ChaosCell:
    """One matrix cell; raises AssertionError (with the repro line) on a
    convergence failure so pytest integration stays natural."""
    cell = ChaosCell(scenario=scenario, mode=mode, seed=seed)
    try:
        if base_dir is None:
            with tempfile.TemporaryDirectory(prefix="chaos-") as tmp:
                fired, retries = _RUNNERS[scenario](tmp, mode, seed)
        else:
            fired, retries = _RUNNERS[scenario](str(base_dir), mode, seed)
        cell.fired, cell.retries_spent = fired, retries
        assert cell.fired >= 1, \
            f"fault point never fired — {scenario} wiring broken?"
        cell.ok = True
    except AssertionError as e:
        cell.error = f"{e}\n  repro: {cell.repro}"
        raise AssertionError(cell.error) from e
    return cell


def run_matrix(seeds: Iterable[int], modes: Iterable[str] = MODES,
               scenarios: Iterable[str] = SCENARIOS,
               fail_fast: bool = False) -> List[ChaosCell]:
    """The full soak. Never raises unless ``fail_fast`` — failed cells come
    back with ``ok=False`` and their repro line in ``error``."""
    cells: List[ChaosCell] = []
    for seed in seeds:
        for scenario in scenarios:
            for mode in modes:
                try:
                    cells.append(run_cell(scenario, mode, seed))
                except AssertionError as e:
                    if fail_fast:
                        raise
                    cells.append(ChaosCell(scenario=scenario, mode=mode,
                                           seed=seed, error=str(e)))
                except Exception as e:      # noqa: BLE001 — soak must
                    bad = ChaosCell(scenario=scenario, mode=mode,  # report
                                    seed=seed)                     # not die
                    bad.error = f"{type(e).__name__}: {e}\n" \
                                f"  repro: {bad.repro}"
                    if fail_fast:
                        raise AssertionError(bad.error) from e
                    cells.append(bad)
    return cells


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", default="0:4",
                    help="'N', 'A:B', 'A:B:S', or the CI shard "
                         "shorthand 'I::S' (shard I of stride S over "
                         f"[0, {SOAK_SEEDS}))")
    ap.add_argument("--modes", default=",".join(MODES))
    ap.add_argument("--scenarios", default=",".join(SCENARIOS))
    ap.add_argument("--repro-out", default=None, metavar="PATH",
                    help="write failed cells' repro lines here (CI "
                         "uploads the file as a per-shard artifact)")
    args = ap.parse_args(argv)
    cells = run_matrix(parse_seeds(args.seeds),
                       modes=args.modes.split(","),
                       scenarios=args.scenarios.split(","))
    bad = [c for c in cells if not c.ok]
    for c in cells:
        mark = "ok " if c.ok else "FAIL"
        print(f"[{mark}] seed={c.seed:<3d} {c.scenario:<8s} {c.mode:<7s} "
              f"fired={c.fired} retries={c.retries_spent}")
    for c in bad:
        print(f"\nFAILED {c.scenario}/{c.mode} seed={c.seed}:\n{c.error}",
              file=sys.stderr)
    if args.repro_out and bad:
        with open(args.repro_out, "w") as f:
            for c in bad:
                f.write(c.repro + "\n")
        print(f"repro lines written to {args.repro_out}", file=sys.stderr)
    print(f"\n{len(cells) - len(bad)}/{len(cells)} cells converged")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
