"""Step watchdog: detects a hung step and fires a recovery callback.

On real clusters a hung collective (dead peer) blocks forever; the watchdog
converts that into a bounded failure the trainer handles via
checkpoint-restore + elastic re-mesh.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional


class Watchdog:
    def __init__(self, timeout_seconds: float,
                 on_timeout: Callable[[], None]):
        self.timeout = timeout_seconds
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def arm(self) -> None:
        self.disarm()
        self.fired = False

        def fire():
            self.fired = True
            self.on_timeout()

        self._timer = threading.Timer(self.timeout, fire)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def __enter__(self):
        self.arm()
        return self

    def __exit__(self, *exc):
        self.disarm()
