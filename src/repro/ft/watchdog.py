"""Step watchdog: detects a hung step and fires a recovery callback.

On real clusters a hung collective (dead peer) blocks forever; the watchdog
converts that into a bounded failure the trainer handles via
checkpoint-restore + elastic re-mesh. Also used per-attempt by
``ft.retry.RetryPolicy`` to turn a hung remote into a deadline failure.

Disarm contract: once ``disarm()`` (or ``arm()``, which re-arms) returns,
the previous timer can no longer set ``fired`` or invoke ``on_timeout`` —
a timer thread racing the disarm is fenced by a generation token checked
under the same lock the disarm holds. A fire that *wins* the race (the
timeout genuinely elapsed before the step completed) still runs; that is a
real timeout, not a race.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional


class Watchdog:
    def __init__(self, timeout_seconds: float,
                 on_timeout: Callable[[], None]):
        self.timeout = timeout_seconds
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self._lock = threading.Lock()
        self._gen = 0               # bumped by every arm/disarm: a pending
        self.fired = False          # fire with a stale token is a no-op

    def arm(self) -> None:
        with self._lock:
            if self._timer is not None:
                self._timer.cancel()
            self._gen += 1
            gen = self._gen
            self.fired = False

            def fire():
                # Timer.cancel() cannot stop a function already running;
                # the token check (under the arm/disarm lock) is what
                # makes a concurrent disarm win deterministically.
                with self._lock:
                    if self._gen != gen:
                        return      # disarmed/re-armed first: stand down
                    self.fired = True
                self.on_timeout()   # outside the lock: callback may re-arm

            self._timer = threading.Timer(self.timeout, fire)
            self._timer.daemon = True
            self._timer.start()

    def disarm(self) -> None:
        with self._lock:
            self._gen += 1          # fence any in-flight fire
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None

    def __enter__(self):
        self.arm()
        return self

    def __exit__(self, *exc):
        self.disarm()
