"""Self-healing retries: bounded attempts, exponential backoff + jitter,
per-operation deadlines.

Why bounded *automatic* retry is correct here (and not a data hazard): every
replication state in this repo is reconstructible from content addresses —
blobs verify against their own hash, descriptors against their content
checksum, images against the config lock, and the manifest rename is the
only commit point. A failed attempt leaves orphans the next attempt
re-verifies (adopting intact bytes, deleting torn ones), so retrying to
convergence can never produce a torn replica; it can only finish the
remainder of the transfer. ``RetryPolicy`` is the control knob:

* ``max_attempts`` — total tries including the first; exhausting them
  QUARANTINES the operation (structured ``RetryHealth`` record, never an
  infinite loop on a persistently-sick destination).
* backoff — exponential (``base_delay_s * multiplier**n``) capped at
  ``max_delay_s``; the *pre-jitter* schedule is monotone non-decreasing by
  construction. Jitter adds a deterministic, seed-derived fraction in
  ``[0, jitter)`` on top — same seed, same schedule, every run (the chaos
  harness depends on this; hypothesis proves it).
* ``deadline_s`` — a per-operation wall budget: no backoff sleep is ever
  started that the deadline could not contain, and attempts stop once it
  is spent. Each attempt may additionally be watched by the existing
  ``ft.Watchdog`` (``attempt_timeout_s``): a call that returns only after
  its watchdog fired is counted as a deadline failure, so a hung remote
  turns into a bounded, observable failure instead of a forever-block.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from .watchdog import Watchdog


@dataclass
class RetryHealth:
    """What an operation's retry loop actually did — the structured health
    record quarantine decisions and telemetry read."""

    attempts: int = 0               # calls made (first try included)
    retries: int = 0                # attempts beyond the first
    succeeded: bool = False
    quarantined: bool = False       # exhausted max_attempts (or deadline)
    deadline_exceeded: bool = False
    backoff_total_s: float = 0.0    # wall time spent sleeping
    wall_s: float = 0.0
    errors: List[str] = field(default_factory=list)

    def record_error(self, exc: BaseException) -> None:
        self.errors.append(f"{type(exc).__name__}: {exc}")


class RetryExhausted(RuntimeError):
    """Raised by ``RetryPolicy.run`` when every attempt failed; carries the
    health record and chains the last underlying error."""

    def __init__(self, msg: str, health: RetryHealth):
        super().__init__(msg)
        self.health = health


def _unit(seed: int, n: int) -> float:
    """Deterministic uniform [0,1) for attempt ``n`` — hash-derived, so the
    jitter schedule is a pure function of (seed, n)."""
    h = hashlib.sha256(f"backoff:{seed}:{n}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.1             # additive fraction in [0, jitter)
    deadline_s: Optional[float] = None
    attempt_timeout_s: Optional[float] = None   # per-attempt Watchdog
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.multiplier < 1.0 or self.base_delay_s < 0 or \
                self.jitter < 0:
            raise ValueError("multiplier >= 1, delays/jitter >= 0 required")

    # ---------------------------------------------------------- schedule
    def schedule(self, n: int) -> float:
        """Pre-jitter delay before retry ``n`` (0-based): exponential,
        capped — monotone non-decreasing in ``n`` by construction."""
        return min(self.base_delay_s * self.multiplier ** n,
                   self.max_delay_s)

    def backoff(self, n: int) -> float:
        """The actual delay before retry ``n``: schedule + deterministic
        seed-derived jitter (same seed => bit-identical schedule)."""
        return self.schedule(n) * (1.0 + self.jitter * _unit(self.seed, n))

    # --------------------------------------------------------------- run
    def execute(self, fn: Callable[[int], Any], *,
                sleep: Callable[[float], None] = time.sleep,
                clock: Callable[[], float] = time.monotonic,
                on_retry: Optional[Callable[[int, BaseException], None]]
                = None) -> Tuple[Optional[Any], RetryHealth]:
        """Run ``fn(attempt)`` (1-based) until it returns, attempts are
        exhausted, or the deadline is spent. Never raises for ``fn``
        failures — returns ``(result_or_None, health)`` so fan-out callers
        can quarantine without unwinding. ``CrashInjected``-style errors
        retry like any other: the next attempt IS the restarted process.
        """
        health = RetryHealth()
        t0 = clock()
        wd = Watchdog(self.attempt_timeout_s, lambda: None) \
            if self.attempt_timeout_s else None
        for attempt in range(1, self.max_attempts + 1):
            health.attempts = attempt
            health.retries = attempt - 1
            try:
                if wd is not None:
                    with wd:
                        result = fn(attempt)
                    if wd.fired:
                        raise TimeoutError(
                            f"attempt {attempt} exceeded "
                            f"{self.attempt_timeout_s}s watchdog")
                else:
                    result = fn(attempt)
                health.succeeded = True
                health.wall_s = clock() - t0
                return result, health
            except Exception as e:       # noqa: BLE001 — every failure
                health.record_error(e)   # class is retryable by design
                if on_retry is not None and attempt < self.max_attempts:
                    on_retry(attempt, e)
            if attempt >= self.max_attempts:
                break
            delay = self.backoff(attempt - 1)
            if self.deadline_s is not None:
                elapsed = clock() - t0
                if elapsed + delay > self.deadline_s:
                    # never start a sleep the deadline cannot contain
                    health.deadline_exceeded = True
                    break
            sleep(delay)
            health.backoff_total_s += delay
        health.quarantined = True
        health.wall_s = clock() - t0
        return None, health

    def run(self, fn: Callable[[int], Any], **kw) -> Any:
        """The raising form of ``execute`` — for single-destination callers
        (``CheckpointFollower``) where exhaustion is an error."""
        result, health = self.execute(fn, **kw)
        if not health.succeeded:
            raise RetryExhausted(
                f"exhausted {health.attempts} attempts "
                f"(deadline_exceeded={health.deadline_exceeded}); last "
                f"error: {health.errors[-1] if health.errors else 'n/a'}",
                health)
        return result
