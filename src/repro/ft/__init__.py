from .straggler import DeadlineSkipper, StragglerStats
from .watchdog import Watchdog
from .elastic import shrink_mesh_shape
from .faults import (CrashInjected, FaultEvent, FaultInjected, FaultInjector,
                     FaultSpec, fault_point, inject, inject_bitrot)
from .retry import RetryExhausted, RetryHealth, RetryPolicy
from .scrub import ScrubFinding, ScrubReport

__all__ = ["DeadlineSkipper", "StragglerStats", "Watchdog",
           "shrink_mesh_shape", "CrashInjected", "FaultEvent",
           "FaultInjected", "FaultInjector", "FaultSpec", "fault_point",
           "inject", "inject_bitrot", "RetryExhausted", "RetryHealth",
           "RetryPolicy", "ScrubFinding", "ScrubReport"]
