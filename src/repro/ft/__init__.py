from .straggler import DeadlineSkipper, StragglerStats
from .watchdog import Watchdog
from .elastic import shrink_mesh_shape

__all__ = ["DeadlineSkipper", "StragglerStats", "Watchdog",
           "shrink_mesh_shape"]
