"""Elastic scaling helpers: choose a new mesh after losing devices.

Policy: keep the model axis intact (TP degree is a property of the
weights' sharding math), shrink the data axis to the largest value that
fits the surviving device count, and drop the remainder (hot spares).
Restore then goes through ckpt.reshard_restore — checkpoints are
mesh-agnostic (logical tensors, chunk-addressed).
"""
from __future__ import annotations

from typing import Tuple


def shrink_mesh_shape(alive_devices: int, model: int = 16,
                      pods: int = 1) -> Tuple[int, ...]:
    """-> (data, model) (or (pod, data, model)) for the surviving devices."""
    per_pod = alive_devices // pods
    data = max(1, per_pod // model)
    if pods > 1:
        return (pods, data, model)
    return (data, model)
