"""CLI for the protocol-invariant analyzer.

    PYTHONPATH=src python -m repro.analysis               # report
    PYTHONPATH=src python -m repro.analysis --check       # CI gate
    PYTHONPATH=src python -m repro.analysis --explain R2  # contract + bug
    PYTHONPATH=src python -m repro.analysis --json out.json

``--check`` exits non-zero on any NEW finding, any STALE baseline
suppression, or any suppression without a reason.  ``--root DIR`` scans
an arbitrary tree (used by the fixture tests) with fixture-mode
defaults: every module in R2 scope, no allowlist, no baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import AnalysisConfig, RULES, run_analysis
from .baseline import diff, load_baseline, write_baseline


def _explain(rule_id: str) -> int:
    rule = RULES.get(rule_id.upper())
    if rule is None:
        print(f"unknown rule {rule_id!r}; known: {', '.join(sorted(RULES))}")
        return 2
    print(f"{rule.id} — {rule.title} [{rule.severity}]")
    print()
    print("CONTRACT")
    print(f"  {rule.contract}")
    print()
    print("MOTIVATING BUG")
    print(f"  {rule.motivation}")
    return 0


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(prog="repro.analysis")
    p.add_argument("--check", action="store_true",
                   help="exit non-zero on new/stale/unreasoned findings")
    p.add_argument("--explain", metavar="RULE",
                   help="print a rule's contract and motivating bug")
    p.add_argument("--json", metavar="PATH",
                   help="write the full findings report as JSON")
    p.add_argument("--write-baseline", action="store_true",
                   help="write current findings to the baseline "
                        "(reasons must then be filled in by hand)")
    p.add_argument("--root", help="scan this tree instead of src/repro "
                                  "(fixture mode: no allowlist/baseline)")
    p.add_argument("--tests-root", help="tests dir for R1 coverage")
    p.add_argument("--chaos", help="chaos module for R1 coverage")
    p.add_argument("--baseline", help="baseline path override")
    p.add_argument("--rules", help="comma-separated rule subset, e.g. R1,R3")
    args = p.parse_args(argv)

    if args.explain:
        return _explain(args.explain)

    if args.root:
        cfg = AnalysisConfig(
            src_root=os.path.abspath(args.root),
            display_root=os.path.abspath(args.root),
            tests_root=args.tests_root,
            chaos_path=args.chaos,
            baseline_path=args.baseline,
        )
    else:
        cfg = AnalysisConfig.for_repo()
        if args.tests_root:
            cfg.tests_root = args.tests_root
        if args.chaos:
            cfg.chaos_path = args.chaos
        if args.baseline:
            cfg.baseline_path = args.baseline

    rules = tuple(r.strip().upper()
                  for r in args.rules.split(",")) if args.rules else None
    findings = run_analysis(cfg, rules=rules)
    baseline = load_baseline(cfg.baseline_path)
    new, suppressed, stale, unreasoned = diff(findings, baseline)

    if args.json:
        report = {
            "findings": [f.to_dict() for f in findings],
            "new": [f.fingerprint for f in new],
            "suppressed": [f.fingerprint for f in suppressed],
            "stale_suppressions": stale,
            "unreasoned_suppressions": unreasoned,
        }
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")

    if args.write_baseline:
        if not cfg.baseline_path:
            print("no baseline path configured")
            return 2
        write_baseline(cfg.baseline_path, findings)
        print(f"wrote {len(findings)} suppression(s) to "
              f"{cfg.baseline_path} — fill in the reasons")
        return 0

    for f in new:
        print(f"NEW        {f.render()}  [fp {f.fingerprint}]")
    for f in suppressed:
        print(f"suppressed {f.render()}  [fp {f.fingerprint}]")
    for e in stale:
        print(f"STALE      {e['rule']} {e['path']} [{e['anchor']}] — "
              f"suppression no longer matches any finding "
              f"[fp {e['fingerprint']}]")
    for e in unreasoned:
        print(f"UNREASONED {e['rule']} {e['path']} [{e['anchor']}] — "
              f"suppression has no reason [fp {e['fingerprint']}]")

    n_rules = len(rules) if rules else len(RULES)
    print(f"\nanalysis: {n_rules} rule(s), {len(findings)} finding(s) "
          f"({len(new)} new, {len(suppressed)} suppressed, "
          f"{len(stale)} stale, {len(unreasoned)} unreasoned)")

    if args.check and (new or stale or unreasoned):
        print("check: FAIL")
        return 1
    if args.check:
        print("check: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
