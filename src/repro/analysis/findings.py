"""Finding: one protocol-invariant violation, stably fingerprinted.

A finding is anchored by ``(rule, path, anchor)`` — *not* by line number —
so the fingerprint survives unrelated edits above the violation.  The
anchor is the enclosing qualified name (``RelayNode._fan_children``) or,
for coverage rules, the fault-point name itself (``chaos-missing:wire.commit``).
Line numbers are carried for display only.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    rule: str       # "R1".."R5"
    severity: str   # "error" | "warning"
    path: str       # display-root-relative module path
    line: int       # 1-based, display only (not part of the fingerprint)
    anchor: str     # line-independent anchor within the module
    message: str

    @property
    def fingerprint(self) -> str:
        raw = f"{self.rule}|{self.path}|{self.anchor}".encode()
        return hashlib.sha256(raw).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "path": self.path,
            "line": self.line,
            "anchor": self.anchor,
            "message": self.message,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        return (f"{self.rule} {self.severity:<7} {self.path}:{self.line} "
                f"[{self.anchor}] {self.message}")


def sort_findings(findings: list[Finding]) -> list[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.anchor))
