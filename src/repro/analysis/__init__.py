"""Protocol-invariant static analyzer for the repro registry/store tree.

Pure-``ast`` (never imports the analyzed code), stdlib-only, seconds to
run — it gates in the CI lint job *before* any heavyweight dependency is
installed.  See ``rules.RULES`` for the five contracts (R1-R5) and
``python -m repro.analysis --explain R2`` for the historical bug behind
each one.  Findings diff against ``baseline.json`` (fingerprint-keyed,
reasoned suppressions); ``--check`` fails on any NEW finding and on any
stale suppression.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

from .ast_utils import ModuleIndex, index_module
from .findings import Finding, sort_findings
from .rules import CRASH_SEAM_ALLOWLIST, RULES, RuleContext, SeamExemption

__all__ = [
    "AnalysisConfig", "run_analysis", "RULES", "Finding",
    "CRASH_SEAM_ALLOWLIST", "SeamExemption",
]

_PKG_DIR = os.path.dirname(os.path.abspath(__file__))


@dataclass
class AnalysisConfig:
    src_root: str
    display_root: str
    tests_root: str | None = None
    chaos_path: str | None = None
    baseline_path: str | None = None
    # None => every scanned module is in R2 scope (fixture mode).
    protocol_dirs: tuple[str, ...] | None = None
    # Dirs (relative to src_root) where '# noqa: BLE001' must map to an
    # allowlist entry.  Empty => noqa consistency not enforced.
    ble_dirs: tuple[str, ...] = ()
    allowlist: tuple[SeamExemption, ...] = ()
    exclude_dirs: tuple[str, ...] = ("__pycache__", "analysis")

    @classmethod
    def for_repo(cls) -> "AnalysisConfig":
        src_root = os.path.dirname(_PKG_DIR)            # src/repro
        repo_root = os.path.dirname(os.path.dirname(src_root))
        tests = os.path.join(repo_root, "tests")
        chaos = os.path.join(src_root, "ft", "chaos.py")
        return cls(
            src_root=src_root,
            display_root=repo_root,
            tests_root=tests if os.path.isdir(tests) else None,
            chaos_path=chaos if os.path.exists(chaos) else None,
            baseline_path=os.path.join(_PKG_DIR, "baseline.json"),
            protocol_dirs=("core", "ft", "serve", "ckpt"),
            ble_dirs=("core", "ft", "serve"),
            allowlist=CRASH_SEAM_ALLOWLIST,
        )


def run_analysis(config: AnalysisConfig,
                 rules: tuple[str, ...] | None = None) -> list[Finding]:
    src = ModuleIndex(config.src_root, config.display_root,
                      exclude_dirs=config.exclude_dirs)
    tests = None
    if config.tests_root and os.path.isdir(config.tests_root):
        tests = ModuleIndex(
            config.tests_root, config.display_root,
            exclude_dirs=config.exclude_dirs + ("fixtures",))
    chaos = None
    if config.chaos_path and os.path.exists(config.chaos_path):
        ap = os.path.abspath(config.chaos_path)
        chaos = index_module(
            ap,
            os.path.relpath(ap, config.display_root),
            os.path.relpath(ap, config.src_root))

    ctx = RuleContext(config, src, tests, chaos)
    findings: list[Finding] = []
    for rule_id, rule in sorted(RULES.items()):
        if rules is not None and rule_id not in rules:
            continue
        findings.extend(rule.check(ctx))
    return sort_findings(findings)
