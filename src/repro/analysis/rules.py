"""The five protocol-invariant rules (R1-R5) and the crash-seam allowlist.

Each rule encodes a convention that an earlier PR shipped a bugfix for —
the analyzer turns reviewer memory into a CI gate.  Rules never excuse
code via the call graph's *precision*; resolution is name-based and
over-approximate, so the graph only ever widens what a rule flags
(R2/R1) or what it credits as covered (R3/R4).

The ``CRASH_SEAM_ALLOWLIST`` is the single source of truth for broad
``except`` seams in ``src/repro/{core,ft,serve}``: every ``# noqa:
BLE001`` in those trees must have an entry here (with a recorded
reason), and every entry must still point at a real broad handler —
both directions are enforced by R2 itself.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Callable

from .ast_utils import (ModuleIndex, attr_chain, calls_in, has_kwarg,
                        str_arg)
from .findings import Finding

FAULT_CALL = "fault_point"
SPEC_CALL = "FaultSpec"
BROAD_EXC = frozenset({"Exception", "BaseException"})
DURABILITY_MARKERS = frozenset({
    "_durability_scope", "_BatchScope", "sync_for_commit",
    "ensure_blob_durable", "fsync",
})
# Modules defining the durability primitive ARE the durability layer.
DURABILITY_IMPL_DEF = "sync_for_commit"
RETENTION_TRIGGERS = frozenset({"remove_image", "prune_steps", "gc"})
RETENTION_MARKERS = frozenset({
    "leased", "lease_holders", "protect_paths", "_protected_paths",
})


@dataclass(frozen=True)
class SeamExemption:
    where: str    # "<display-relative path>::<qualname>"
    reason: str


CRASH_SEAM_ALLOWLIST: tuple[SeamExemption, ...] = (
    SeamExemption(
        "src/repro/core/registry.py::RelayNode.negotiate",
        "per-child isolation: a child that dies (CrashInjected) or drops "
        "during negotiate is marked failed and retried by _retry_failed; "
        "the relay itself crashes only at its own fault points"),
    SeamExemption(
        "src/repro/core/registry.py::RelayNode.probe_blobs",
        "per-child isolation: probe failure marks the child failed "
        "instead of killing the whole fan-out"),
    SeamExemption(
        "src/repro/core/registry.py::RelayNode.receive_blob",
        "per-child isolation: a child dying mid-forward must not abort "
        "the remaining children's writes"),
    SeamExemption(
        "src/repro/core/registry.py::RelayNode._fan_children",
        "per-child isolation during layer fan and finalize; failed "
        "children are re-pushed by _retry_failed or quarantined"),
    SeamExemption(
        "src/repro/core/registry.py::_retry_failed",
        "retry loop: a child's CrashInjected means THAT child died; the "
        "next attempt is its restarted process (kill-matrix semantics), "
        "exhaustion quarantines the child instead of raising"),
    SeamExemption(
        "src/repro/core/registry.py::replicate_fanout.plan",
        "per-replica isolation: one replica failing negotiate/plan must "
        "not stop the others; failure is recorded via fail(i, e)"),
    SeamExemption(
        "src/repro/core/registry.py::replicate_fanout.receive",
        "per-replica isolation during blob shipping; recorded via "
        "fail(i, e) and surfaced in the fan-out report"),
    SeamExemption(
        "src/repro/core/registry.py::replicate_fanout.safe_finalize",
        "per-replica isolation at commit: a replica that dies before "
        "finalize stays uncommitted (torn-free) and is reported failed"),
    SeamExemption(
        "src/repro/core/store.py::LayerStore.gc",
        "a broken gc hook must never break the sweep; CrashInjected is "
        "re-raised by the preceding handler so kill-matrix crashes "
        "still propagate"),
    SeamExemption(
        "src/repro/ft/retry.py::RetryPolicy.execute",
        "deliberately retries CrashInjected: the next attempt IS the "
        "restarted process, which is exactly what the kill matrix "
        "simulates (PR 7); exhaustion re-raises"),
    SeamExemption(
        "src/repro/ft/chaos.py::run_matrix",
        "soak harness: every cell failure must be collected into the "
        "one-line repro report instead of aborting the matrix"),
    SeamExemption(
        "src/repro/serve/engine.py::CheckpointFollower.poll",
        "bookkeeping only: counts consecutive poll errors, then "
        "re-raises unconditionally (compliant; listed for BLE001)"),
    SeamExemption(
        "src/repro/serve/engine.py::CheckpointFollower._repair_revision",
        "verify-gated repair degrades to 'revision stays unverified' on "
        "peer errors; CrashInjected is re-raised by the preceding "
        "handler so simulated SIGKILLs still surface from poll()"),
    SeamExemption(
        "src/repro/serve/engine.py::CheckpointFollower.poll_and_refresh",
        "refresh failure rolls the engine back to the last good "
        "revision; Engine.refresh is an in-memory swap that reaches no "
        "fault point"),
)


@dataclass(frozen=True)
class Rule:
    id: str
    title: str
    severity: str
    contract: str
    motivation: str
    check: Callable[["RuleContext"], list[Finding]]


class RuleContext:
    def __init__(self, config, src: ModuleIndex,
                 tests: ModuleIndex | None,
                 chaos: "object | None") -> None:
        self.config = config
        self.src = src
        self.tests = tests
        self.chaos = chaos  # ModuleInfo parsed from config.chaos_path


# --------------------------------------------------------------------------
# helpers

def _fault_point_sites(ctx: RuleContext):
    """Yield (fn, call, point-or-None) for every fault_point() in src."""
    for fn in ctx.src.all_functions():
        for cs in fn.calls:
            if cs.name == FAULT_CALL:
                yield fn, cs.node, str_arg(cs.node, 0, "point")


def _spec_points(index: ModuleIndex | None, extra_mod=None):
    """Yield (path, lineno, point) for literal FaultSpec(point=...) args."""
    mods = list(index.modules.values()) if index is not None else []
    if extra_mod is not None:
        mods.append(extra_mod)
    for mod in mods:
        for fn in mod.functions.values():
            for cs in fn.calls:
                if cs.name != SPEC_CALL:
                    continue
                point = str_arg(cs.node, 0, "point")
                if point is not None:
                    yield mod.path, cs.lineno, point


def _exc_names(t: ast.AST | None) -> set[str]:
    if t is None:
        return set()
    elts = t.elts if isinstance(t, ast.Tuple) else [t]
    out: set[str] = set()
    for e in elts:
        if isinstance(e, ast.Name):
            out.add(e.id)
        elif isinstance(e, ast.Attribute):
            out.add(e.attr)
    return out


def _is_broad(h: ast.ExceptHandler) -> bool:
    return h.type is None or bool(_exc_names(h.type) & BROAD_EXC)


def _reraises(h: ast.ExceptHandler) -> bool:
    for n in ast.walk(h):
        if isinstance(n, ast.Raise):
            if n.exc is None:
                return True
            if (h.name and isinstance(n.exc, ast.Name)
                    and n.exc.id == h.name):
                return True
    return False


def _crash_guarded(handlers: list[ast.ExceptHandler],
                   upto: int) -> bool:
    """True when a handler BEFORE index ``upto`` re-raises CrashInjected."""
    for h in handlers[:upto]:
        if "CrashInjected" in _exc_names(h.type):
            if any(isinstance(n, ast.Raise) for n in ast.walk(h)):
                return True
    return False


def _in_dirs(mod, dirs: tuple[str, ...] | None) -> bool:
    if dirs is None:
        return True
    top = mod.src_rel.replace("\\", "/").split("/", 1)[0]
    return top in dirs


# --------------------------------------------------------------------------
# R1: fault-point coverage

def _check_r1(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    chaos_strings = ctx.chaos.strings if ctx.chaos is not None else None
    test_strings: set[str] = set()
    if ctx.tests is not None:
        for mod in ctx.tests.modules.values():
            test_strings |= mod.strings

    src_points: dict[str, tuple[str, int]] = {}
    for fn, call, point in _fault_point_sites(ctx):
        if point is None:
            out.append(Finding(
                "R1", "error", fn.path, call.lineno,
                f"nonliteral:{fn.qualname}",
                "fault_point() name is not a string literal — coverage "
                "cannot be checked statically"))
            continue
        src_points.setdefault(point, (fn.path, call.lineno))

    for point, (path, line) in sorted(src_points.items()):
        if chaos_strings is not None and point not in chaos_strings:
            out.append(Finding(
                "R1", "error", path, line, f"chaos-missing:{point}",
                f"fault point {point!r} is not exercised by the chaos "
                "scenario matrix (no literal occurrence in the chaos "
                "module)"))
        if ctx.tests is not None and point not in test_strings:
            out.append(Finding(
                "R1", "error", path, line, f"test-missing:{point}",
                f"fault point {point!r} never appears in any test — a "
                "dead kill-matrix cell proves nothing"))

    known = sorted(src_points)
    for path, line, point in _spec_points(ctx.tests, ctx.chaos):
        if point.endswith("*"):
            prefix = point[:-1]
            ok = any(p.startswith(prefix) for p in known)
        else:
            ok = point in known
        if not ok:
            out.append(Finding(
                "R1", "error", path, line, f"dead-spec:{point}",
                f"FaultSpec targets {point!r} but no such fault point "
                "exists in src — dead or typo'd injection"))
    return out


# --------------------------------------------------------------------------
# R2: crash-seam soundness

def _check_r2(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    cfg = ctx.config
    allow = {e.where: e for e in cfg.allowlist}
    tainted = ctx.src.fault_tainted()
    dynamic = ctx.src.dynamic_tainted()
    seen_sites: set[str] = set()

    for mod in ctx.src.modules.values():
        in_scope = _in_dirs(mod, cfg.protocol_dirs)
        ble_scoped = _in_dirs(mod, cfg.ble_dirs) if cfg.ble_dirs else False
        for fn in mod.functions.values():
            where = f"{fn.path}::{fn.qualname}"
            for t in fn.trys:
                for i, h in enumerate(t.handlers):
                    if not _is_broad(h):
                        continue
                    seen_sites.add(where)
                    if ble_scoped:
                        line = mod.lines[h.lineno - 1] if (
                            h.lineno <= len(mod.lines)) else ""
                        if "noqa: BLE001" in line and where not in allow:
                            out.append(Finding(
                                "R2", "error", fn.path, h.lineno,
                                f"noqa-unlisted:{fn.qualname}",
                                "broad handler carries '# noqa: BLE001' "
                                "but has no CRASH_SEAM_ALLOWLIST entry — "
                                "the allowlist is the single source of "
                                "truth for blind-except exemptions"))
                    if not in_scope:
                        continue
                    if _reraises(h) or _crash_guarded(t.handlers, i):
                        continue
                    names, dyn = calls_in(ast.Module(body=t.body,
                                                     type_ignores=[]), mod)
                    reaches = FAULT_CALL in names or any(
                        g in tainted
                        for n in names for g in ctx.src.by_name.get(n, ()))
                    unprovable = dyn or any(
                        g in dynamic
                        for n in names for g in ctx.src.by_name.get(n, ()))
                    if not (reaches or unprovable):
                        continue
                    if where in allow:
                        continue
                    why = ("can reach a fault_point call"
                           if reaches else
                           "dispatches dynamically, so it cannot be "
                           "proven CrashInjected-free")
                    out.append(Finding(
                        "R2", "error", fn.path, h.lineno,
                        f"swallow:{fn.qualname}",
                        f"broad except in {fn.qualname} {why} but neither "
                        "re-raises, is CrashInjected-guarded, nor is "
                        "allowlisted — a swallowed CrashInjected voids "
                        "the SIGKILL kill matrix"))

    for where, exemption in sorted(allow.items()):
        if where not in seen_sites:
            out.append(Finding(
                "R2", "error", where.split("::")[0], 1,
                f"stale-exemption:{where.split('::')[1]}",
                f"CRASH_SEAM_ALLOWLIST entry {where!r} matches no "
                "existing broad except handler — remove the stale entry"))
        elif not exemption.reason.strip():
            out.append(Finding(
                "R2", "error", where.split("::")[0], 1,
                f"unreasoned-exemption:{where.split('::')[1]}",
                f"CRASH_SEAM_ALLOWLIST entry {where!r} has no reason "
                "recorded"))
    return out


# --------------------------------------------------------------------------
# R3: durability discipline

def _os_replace_calls(fn) -> list[ast.Call]:
    hits = []
    for cs in fn.calls:
        if cs.name == "replace":
            f = cs.node.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "os"):
                hits.append(cs.node)
    return hits


def _check_r3(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    impl_modules = {mod.path for mod in ctx.src.modules.values()
                    if DURABILITY_IMPL_DEF in mod.def_names}
    seeds = {fn for fn in ctx.src.all_functions()
             if fn.names & DURABILITY_MARKERS}
    covered = ctx.src.propagate_down(seeds)

    for mod in ctx.src.modules.values():
        if mod.path in impl_modules:
            continue  # the durability layer itself
        for fn in mod.functions.values():
            if fn in covered:
                continue
            writes = []
            for cs in fn.calls:
                if cs.name not in ("write_blob", "write_layer"):
                    continue
                # A resolved callee that is itself durable (the store
                # primitives fsync or defer to the batch scope) covers
                # the caller; os.replace never gets this credit.
                callees = ctx.src.by_name.get(cs.name, ())
                if callees and all(g.names & DURABILITY_MARKERS
                                   or g in covered for g in callees):
                    continue
                writes.append(cs.node)
            writes += _os_replace_calls(fn)
            if not writes:
                continue
            line = min(w.lineno for w in writes)
            out.append(Finding(
                "R3", "error", fn.path, line,
                f"undominated-write:{fn.qualname}",
                f"{fn.qualname} writes blob/layer/manifest state but no "
                "durability scope dominates it (no _durability_scope/"
                "_BatchScope/sync_for_commit/ensure_blob_durable/fsync "
                "on any path into it) — a crash can tear the write"))
    return out


# --------------------------------------------------------------------------
# R4: retention discipline

def _check_r4(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    for mod in ctx.src.modules.values():
        for fn in mod.functions.values():
            triggers = [cs for cs in fn.calls
                        if cs.name in RETENTION_TRIGGERS]
            if not triggers:
                continue
            if fn.names & RETENTION_MARKERS:
                continue
            for cs in triggers:
                if has_kwarg(cs.node, "force"):
                    continue
                callees = ctx.src.by_name.get(cs.name, ())
                if any(g.names & RETENTION_MARKERS for g in callees):
                    continue
                out.append(Finding(
                    "R4", "warning", fn.path, cs.lineno,
                    f"unleased-retention:{fn.qualname}:{cs.name}",
                    f"{fn.qualname} calls {cs.name}() but neither it nor "
                    "the callee consults leased/lease_holders/"
                    "protect_paths, and no force= is passed — retention "
                    "can delete blobs out from under a live lease"))
    return out


# --------------------------------------------------------------------------
# R5: holdings-cache invalidation (store.py)

_HOLDINGS_APPLY = frozenset({"_holdings_apply_commit",
                             "_holdings_apply_remove"})


def _chain_has(node: ast.AST, name: str) -> bool:
    return name in attr_chain(node)


def _check_r5(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    for mod in ctx.src.modules.values():
        if mod.path.rsplit("/", 1)[-1] != "store.py":
            continue
        for fn in mod.functions.values():
            tag_mutations: list[int] = []
            for cs in fn.calls:
                if cs.name in ("pop", "clear"):
                    if _chain_has(cs.node.func, "_tags_cache"):
                        tag_mutations.append(cs.lineno)
            for n in ast.walk(fn.node):
                if isinstance(n, ast.Delete):
                    for tgt in n.targets:
                        base = tgt.value if isinstance(
                            tgt, ast.Subscript) else tgt
                        if _chain_has(base, "_tags_cache"):
                            tag_mutations.append(n.lineno)
            holdings_updated = any(
                cs.name in _HOLDINGS_APPLY for cs in fn.calls)
            if tag_mutations and not holdings_updated:
                out.append(Finding(
                    "R5", "error", fn.path, min(tag_mutations),
                    f"stale-holdings:{fn.qualname}",
                    f"{fn.qualname} invalidates _tags_cache (committed-"
                    "tag state) without updating holdings_index via "
                    "_holdings_apply_commit/_holdings_apply_remove — "
                    "holdings would serve deleted or stale tags"))

            if fn.qualname.endswith("__init__"):
                continue
            out.extend(_check_holdings_lock(fn))
    return out


def _check_holdings_lock(fn) -> list[Finding]:
    """Writes to _holdings_cache/_holdings_aux must sit under the lock."""
    out: list[Finding] = []

    def is_holdings(node: ast.AST) -> bool:
        return (_chain_has(node, "_holdings_cache")
                or _chain_has(node, "_holdings_aux"))

    def walk(node: ast.AST, locked: bool) -> None:
        if isinstance(node, ast.With):
            if any(_chain_has(item.context_expr, "_holdings_lock")
                   for item in node.items):
                locked = True
        if not locked:
            bad_line = None
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets
                           if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    base = tgt.value if isinstance(
                        tgt, ast.Subscript) else tgt
                    if is_holdings(base):
                        bad_line = node.lineno
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in ("pop", "clear", "update",
                                       "setdefault")
                        and is_holdings(f.value)):
                    bad_line = node.lineno
            if bad_line is not None:
                out.append(Finding(
                    "R5", "error", fn.path, bad_line,
                    f"unlocked-holdings:{fn.qualname}:{bad_line}",
                    f"{fn.qualname} mutates the holdings cache outside "
                    "'with self._holdings_lock' — racing readers can "
                    "see a torn index"))
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # nested defs are separate FunctionInfos
            walk(child, locked)

    walk(fn.node, False)
    return out


# --------------------------------------------------------------------------

RULES: dict[str, Rule] = {r.id: r for r in (
    Rule(
        id="R1",
        title="fault-point coverage",
        severity="error",
        contract=(
            "Every fault_point(\"name\", ...) call site in src must "
            "appear (as a string literal) in the ft/chaos.py scenario "
            "matrix AND in at least one test, and every point a "
            "FaultSpec names in chaos/tests must exist in src "
            "(wildcards match by prefix).  Fault-point names must be "
            "string literals."),
        motivation=(
            "PR 7's kill matrix asserts fired >= 1 per cell precisely "
            "because a cell whose injection never fires proves nothing; "
            "an uncovered or typo'd point is a silent no-op cell — the "
            "crash seam it was meant to exercise ships untested."),
        check=_check_r1,
    ),
    Rule(
        id="R2",
        title="crash-seam soundness",
        severity="error",
        contract=(
            "A broad except (bare / Exception / BaseException) whose "
            "try body can reach a fault_point call — transitively "
            "through the call graph, with dynamic dispatch treated as "
            "reaching — must re-raise, be preceded by an 'except "
            "CrashInjected: raise' handler, or carry a reasoned "
            "CRASH_SEAM_ALLOWLIST entry.  Scope: src/repro/{core,ft,"
            "serve,ckpt}.  Every '# noqa: BLE001' in {core,ft,serve} "
            "must map to an allowlist entry, and every entry must match "
            "a live broad handler."),
        motivation=(
            "CrashInjected is the kill matrix's simulated SIGKILL: a "
            "handler that swallows it makes the 'process died here' "
            "cell silently pass (the PR 7 retry-loop bug).  The two "
            "historical noqa seams (registry _retry_failed, "
            "RetryPolicy.execute) are now structured allowlist entries "
            "with recorded reasons."),
        check=_check_r2,
    ),
    Rule(
        id="R3",
        title="durability discipline",
        severity="error",
        contract=(
            "Any function that writes blob/layer/manifest state "
            "(write_blob / write_layer / os.replace) outside the "
            "durability layer itself must be dominated by a durability "
            "scope: it (or a transitive caller) must mention "
            "_durability_scope / _BatchScope / sync_for_commit / "
            "ensure_blob_durable / fsync."),
        motivation=(
            "The passive registry's _write originally renamed the "
            "bundle index into place with os.replace but never fsynced "
            "— a crash after rename could publish a torn index (fixed "
            "in this PR).  The store's flush-before-leaving-scope "
            "invariant only protects writes that sit inside a scope."),
        check=_check_r3,
    ),
    Rule(
        id="R4",
        title="retention discipline",
        severity="warning",
        contract=(
            "Any function invoking remove_image / prune_steps / gc "
            "must consult leased / lease_holders / protect_paths on "
            "some path — in its own body or in the callee — or "
            "explicitly pass force=."),
        motivation=(
            "PR 6's cross-image gc originally swept blobs that a "
            "concurrent reader held a lease on; retention paths now "
            "must prove they looked at the lease table (or say force=) "
            "before deleting."),
        check=_check_r4,
    ),
    Rule(
        id="R5",
        title="holdings-cache invalidation",
        severity="error",
        contract=(
            "In store.py, any method that invalidates committed-tag "
            "state (_tags_cache pop/clear/del) must also update "
            "holdings_index via _holdings_apply_commit / "
            "_holdings_apply_remove, and every write to the holdings "
            "cache must sit inside 'with self._holdings_lock'."),
        motivation=(
            "The namespace-wide holdings index (PR 6) is an incremental "
            "cache over committed tags; PR 8's scrub work hit a path "
            "where tags changed but holdings stayed stale, serving "
            "blobs for a deleted tag.  Lock discipline keeps the "
            "incremental update race-free."),
        check=_check_r5,
    ),
)}
