"""AST indexing and call-graph approximation for the invariant analyzer.

Pure ``ast`` — the analyzed tree is never imported, so the pass runs in
the dependency-free CI lint job (``repro`` is a namespace package and
``repro.analysis`` pulls in nothing outside the stdlib).

The call graph is a deliberate over-approximation suited to gating, not
to precision:

* a call is **named** when its callee is a plain name that resolves to a
  module-level/nested def, an import, or a builtin — or any attribute
  access (``obj.commit(...)`` contributes the name ``commit``);
* a call is **dynamic** when the callee is an unresolvable bare name
  (``fn()``, ``hook(self)``), a subscript (``_RUNNERS[s](...)``), or any
  other computed expression.  Dynamic dispatch cannot be proven
  ``CrashInjected``-free, so taint analyses treat it as contaminating.

Name-based resolution links a call name to *every* function in the index
whose qualified name ends with that segment.  That conflates unrelated
``commit`` methods — acceptable: the rules only ever use the graph to
widen taint, never to excuse code.
"""
from __future__ import annotations

import ast
import builtins
import os
from dataclasses import dataclass, field

_BUILTIN_NAMES = frozenset(dir(builtins))


@dataclass(eq=False)
class CallSite:
    name: str | None    # last-segment callee name; None when dynamic
    lineno: int
    node: ast.Call


@dataclass(eq=False)
class FunctionInfo:
    qualname: str       # "Class.method" / "outer.inner" / "<module>"
    path: str           # display-root-relative module path
    lineno: int
    node: ast.AST
    calls: list[CallSite] = field(default_factory=list)
    names: set[str] = field(default_factory=set)   # identifiers, attrs, kwargs
    trys: list[ast.Try] = field(default_factory=list)

    @property
    def call_names(self) -> set[str]:
        return {c.name for c in self.calls if c.name is not None}

    @property
    def has_dynamic_call(self) -> bool:
        return any(c.name is None for c in self.calls)


@dataclass(eq=False)
class ModuleInfo:
    path: str                       # display-root-relative
    src_rel: str                    # scan-root-relative (for dir scoping)
    tree: ast.Module
    lines: list[str]
    imports: set[str]
    def_names: set[str]
    functions: dict[str, FunctionInfo]
    strings: set[str]


def classify_call(call: ast.Call, imports: set[str],
                  def_names: set[str]) -> CallSite:
    f = call.func
    if isinstance(f, ast.Name):
        nm = f.id
        if nm in def_names or nm in imports or nm in _BUILTIN_NAMES:
            return CallSite(nm, call.lineno, call)
        return CallSite(None, call.lineno, call)  # local var / param: dynamic
    if isinstance(f, ast.Attribute):
        return CallSite(f.attr, call.lineno, call)
    return CallSite(None, call.lineno, call)      # subscript, lambda, etc.


def index_module(abspath: str, path: str, src_rel: str) -> ModuleInfo:
    with open(abspath, encoding="utf-8") as fh:
        source = fh.read()
    tree = ast.parse(source, filename=abspath)

    imports: set[str] = set()
    def_names: set[str] = set()
    strings: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                imports.add(alias.asname or alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                imports.add(alias.asname or alias.name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            def_names.add(node.name)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            strings.add(node.value)

    functions: dict[str, FunctionInfo] = {}

    def record(node: ast.AST, fn: FunctionInfo) -> None:
        if isinstance(node, ast.Call):
            fn.calls.append(classify_call(node, imports, def_names))
        elif isinstance(node, ast.Name):
            fn.names.add(node.id)
        elif isinstance(node, ast.Attribute):
            fn.names.add(node.attr)
        elif isinstance(node, ast.keyword) and node.arg:
            fn.names.add(node.arg)
        elif isinstance(node, ast.Try):
            fn.trys.append(node)

    def walk(node: ast.AST, stack: list[str], fn: FunctionInfo) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = ".".join(stack + [child.name]) if stack else child.name
                info = FunctionInfo(qual, path, child.lineno, child)
                functions[qual] = info
                walk(child, stack + [child.name], info)
            elif isinstance(child, ast.ClassDef):
                walk(child, stack + [child.name], fn)
            else:
                record(child, fn)
                walk(child, stack, fn)

    module_fn = FunctionInfo("<module>", path, 1, tree)
    functions["<module>"] = module_fn
    walk(tree, [], module_fn)

    return ModuleInfo(path, src_rel, tree, source.splitlines(),
                      imports, def_names, functions, strings)


class ModuleIndex:
    """Every ``*.py`` under ``root``, with a name-resolved call graph."""

    def __init__(self, root: str, display_root: str,
                 exclude_dirs: tuple[str, ...] = ("__pycache__",)) -> None:
        self.root = os.path.abspath(root)
        self.display_root = os.path.abspath(display_root)
        self.modules: dict[str, ModuleInfo] = {}
        for dirpath, dirnames, filenames in os.walk(self.root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in exclude_dirs)
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                ap = os.path.join(dirpath, name)
                path = os.path.relpath(ap, self.display_root)
                src_rel = os.path.relpath(ap, self.root)
                self.modules[path] = index_module(ap, path, src_rel)

        self.by_name: dict[str, list[FunctionInfo]] = {}
        for mod in self.modules.values():
            for info in mod.functions.values():
                last = info.qualname.rsplit(".", 1)[-1]
                self.by_name.setdefault(last, []).append(info)

        self._fault_tainted: set[FunctionInfo] | None = None
        self._dynamic_tainted: set[FunctionInfo] | None = None

    def all_functions(self):
        for mod in self.modules.values():
            yield from mod.functions.values()

    def module_of(self, fn: FunctionInfo) -> ModuleInfo:
        return self.modules[fn.path]

    def _propagate_up(self, seeds: set[FunctionInfo]) -> set[FunctionInfo]:
        """Close ``seeds`` under "calls a member" (callers get tainted)."""
        tainted = set(seeds)
        changed = True
        while changed:
            changed = False
            for fn in self.all_functions():
                if fn in tainted:
                    continue
                for name in fn.call_names:
                    if any(g in tainted for g in self.by_name.get(name, ())):
                        tainted.add(fn)
                        changed = True
                        break
        return tainted

    def propagate_down(self, seeds: set[FunctionInfo]) -> set[FunctionInfo]:
        """Close ``seeds`` under "is called by a member" (callees join)."""
        covered = set(seeds)
        changed = True
        while changed:
            changed = False
            for fn in list(covered):
                for name in fn.call_names:
                    for g in self.by_name.get(name, ()):
                        if g not in covered:
                            covered.add(g)
                            changed = True
        return covered

    def fault_tainted(self) -> set[FunctionInfo]:
        """Functions that may reach a ``fault_point`` call (transitive)."""
        if self._fault_tainted is None:
            seeds = {fn for fn in self.all_functions()
                     if "fault_point" in fn.call_names}
            self._fault_tainted = self._propagate_up(seeds)
        return self._fault_tainted

    def dynamic_tainted(self) -> set[FunctionInfo]:
        """Functions that may reach dynamic dispatch (unprovable reach)."""
        if self._dynamic_tainted is None:
            seeds = {fn for fn in self.all_functions()
                     if fn.has_dynamic_call}
            self._dynamic_tainted = self._propagate_up(seeds)
        return self._dynamic_tainted


def calls_in(node: ast.AST, mod: ModuleInfo) -> tuple[set[str], bool]:
    """(named callees, saw-dynamic-call) over an arbitrary subtree."""
    names: set[str] = set()
    dynamic = False
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            cs = classify_call(n, mod.imports, mod.def_names)
            if cs.name is None:
                dynamic = True
            else:
                names.add(cs.name)
    return names, dynamic


def attr_chain(node: ast.AST) -> list[str]:
    """``self.a.b`` -> ["self", "a", "b"]; [] when not a name/attr chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def str_arg(call: ast.Call, pos: int, kwarg: str) -> str | None:
    """Literal string at positional ``pos`` or keyword ``kwarg``, else None."""
    if len(call.args) > pos:
        a = call.args[pos]
        if isinstance(a, ast.Constant) and isinstance(a.value, str):
            return a.value
        return None
    for kw in call.keywords:
        if kw.arg == kwarg:
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return v.value
            return None
    return None


def has_kwarg(call: ast.Call, name: str) -> bool:
    return any(kw.arg == name for kw in call.keywords)
