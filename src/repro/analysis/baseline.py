"""Baseline diffing: fingerprint-keyed, reasoned suppressions.

``baseline.json`` holds the findings the tree has consciously accepted —
each entry MUST carry a non-empty ``reason``.  ``--check`` fails on:

* **new** findings (present in the tree, absent from the baseline),
* **stale** suppressions (baselined fingerprint no longer produced —
  the debt was paid; the entry must be deleted in the same PR),
* **unreasoned** suppressions (entry without a reason string).

Fingerprints anchor on ``(rule, path, qualname-or-point)`` — not line
numbers — so unrelated edits never churn the baseline.
"""
from __future__ import annotations

import json
import os

from .findings import Finding

VERSION = 1


def load_baseline(path: str | None) -> dict[str, dict]:
    if not path or not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    entries = data.get("suppressions", [])
    return {e["fingerprint"]: e for e in entries}


def write_baseline(path: str, findings: list[Finding]) -> None:
    entries = [dict(f.to_dict(), reason="TODO: justify this suppression")
               for f in findings]
    payload = {"version": VERSION, "suppressions": entries}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")


def diff(findings: list[Finding], baseline: dict[str, dict]):
    """-> (new_findings, suppressed_findings, stale_entries, unreasoned)."""
    new: list[Finding] = []
    suppressed: list[Finding] = []
    seen: set[str] = set()
    for f in findings:
        seen.add(f.fingerprint)
        if f.fingerprint in baseline:
            suppressed.append(f)
        else:
            new.append(f)
    stale = [e for fp, e in sorted(baseline.items()) if fp not in seen]
    unreasoned = []
    for fp, e in sorted(baseline.items()):
        if fp not in seen:
            continue
        reason = str(e.get("reason", "")).strip()
        if not reason or reason.startswith("TODO"):
            unreasoned.append(e)
    return new, suppressed, stale, unreasoned
