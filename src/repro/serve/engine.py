"""Batched serving engine: prefill once, decode step-by-step.

Single-host convenience wrapper over models.prefill / models.decode_step
(the production path jits the same functions through train.make_*_step with
mesh shardings — see launch/serve.py). Supports greedy and temperature
sampling, per-sequence stop tokens, and batched requests padded to a
common length.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, steps)
    logits_last: np.ndarray


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, t: prefill(cfg, p, t))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def generate(self, prompts: np.ndarray, steps: int,
                 temperature: float = 0.0, seed: int = 0,
                 stop_token: Optional[int] = None) -> GenerationResult:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for fixed-length synthetic prompts)."""
        B, S = prompts.shape
        assert S + steps <= self.max_len or self.cfg.window, \
            "prompt + steps exceeds cache"
        cache = init_cache(self.cfg, B, self.max_len)
        # prefill builds a cache sized cache_len(S); splice it into the
        # full-size decode cache ring-consistently
        pf_cache, logits = self._prefill(self.params, jnp.asarray(prompts))
        cache = self._splice(cache, pf_cache, S)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((B, steps), np.int32)
        logits_np = None
        tok = self._sample(logits, temperature, key)
        for i in range(steps):
            out[:, i] = np.asarray(tok)
            cache, logits = self._decode(self.params, cache, tok,
                                         jnp.int32(S + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
            if stop_token is not None and bool((out[:, i] == stop_token).all()):
                out = out[:, :i + 1]
                break
        logits_np = np.asarray(logits)
        return GenerationResult(tokens=out, logits_last=logits_np)

    def _sample(self, logits, temperature: float, key):
        logits = logits[..., :self.cfg.vocab]
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1) \
            .astype(jnp.int32)

    def _splice(self, cache, pf_cache, S: int):
        """Insert prefill cache (length C_pf, ring layout) into the decode
        cache (length C_full) preserving slot = pos % C semantics."""
        def one(full, pf):
            if full.shape == pf.shape:
                return pf            # ssm states / same-length caches
            C_full, C_pf = full.shape[2], pf.shape[2]
            # prefill ring holds positions S-C_pf..S-1 at slot pos % C_pf;
            # unroll to chronological then place at pos % C_full.
            start = S - C_pf
            idx = (start + np.arange(C_pf)) % C_pf        # chronological
            chron = jnp.take(pf, jnp.asarray(idx), axis=2)
            slots = (start + np.arange(C_pf)) % C_full
            return full.at[:, :, jnp.asarray(slots)].set(chron)
        return jax.tree.map(one, cache, pf_cache)
