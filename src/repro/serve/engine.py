"""Batched serving engine: prefill once, decode step-by-step.

Single-host convenience wrapper over models.prefill / models.decode_step
(the production path jits the same functions through train.make_*_step with
mesh shardings — see launch/serve.py). Supports greedy and temperature
sampling, per-sequence stop tokens, and batched requests padded to a
common length.

``CheckpointFollower`` closes the §III.C redeployment loop for serving:
instead of re-downloading whole checkpoints, it pulls per-save DELTAS from
the training store (core.registry.pull_delta — one have-set negotiation,
only changed chunks over the wire, incremental verification) and hands the
refreshed params to ``Engine.refresh`` — weight hot-swap without
recompiling the jitted prefill/decode functions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import LayerStore, PushStats, pull_delta
from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, steps)
    logits_last: np.ndarray


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, t: prefill(cfg, p, t))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def refresh(self, params) -> None:
        """Hot-swap weights (e.g. from CheckpointFollower.poll). Params are
        a jit argument, so same-shape updates reuse the compiled
        prefill/decode executables — no retrace, no downtime."""
        self.params = params

    def generate(self, prompts: np.ndarray, steps: int,
                 temperature: float = 0.0, seed: int = 0,
                 stop_token: Optional[int] = None) -> GenerationResult:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for fixed-length synthetic prompts)."""
        B, S = prompts.shape
        assert S + steps <= self.max_len or self.cfg.window, \
            "prompt + steps exceeds cache"
        cache = init_cache(self.cfg, B, self.max_len)
        # prefill builds a cache sized cache_len(S); splice it into the
        # full-size decode cache ring-consistently
        pf_cache, logits = self._prefill(self.params, jnp.asarray(prompts))
        cache = self._splice(cache, pf_cache, S)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((B, steps), np.int32)
        logits_np = None
        tok = self._sample(logits, temperature, key)
        for i in range(steps):
            out[:, i] = np.asarray(tok)
            cache, logits = self._decode(self.params, cache, tok,
                                         jnp.int32(S + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
            if stop_token is not None and bool((out[:, i] == stop_token).all()):
                out = out[:, :i + 1]
                break
        logits_np = np.asarray(logits)
        return GenerationResult(tokens=out, logits_last=logits_np)

    def _sample(self, logits, temperature: float, key):
        logits = logits[..., :self.cfg.vocab]
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1) \
            .astype(jnp.int32)

    def _splice(self, cache, pf_cache, S: int):
        """Insert prefill cache (length C_pf, ring layout) into the decode
        cache (length C_full) preserving slot = pos % C semantics."""
        def one(full, pf):
            if full.shape == pf.shape:
                return pf            # ssm states / same-length caches
            C_full, C_pf = full.shape[2], pf.shape[2]
            # prefill ring holds positions S-C_pf..S-1 at slot pos % C_pf;
            # unroll to chronological then place at pos % C_full.
            start = S - C_pf
            idx = (start + np.arange(C_pf)) % C_pf        # chronological
            chron = jnp.take(pf, jnp.asarray(idx), axis=2)
            slots = (start + np.arange(C_pf)) % C_full
            return full.at[:, :, jnp.asarray(slots)].set(chron)
        return jax.tree.map(one, cache, pf_cache)


class CheckpointFollower:
    """Keep a serving store in sync with a training store by pulling
    per-save deltas (see module docstring).

    ``remote`` is the training-side LayerStore (or its path); ``local`` is
    this server's store. ``poll()`` pulls any checkpoint newer than the
    last one seen — O(changed bytes) on the wire — and returns
    (step, params, opt_state) ready for ``Engine.refresh``, or None when
    already up to date. The local store keeps the ``keep`` newest
    checkpoints and mark-and-sweeps the rest after each pull, so a
    long-running replica's disk stays bounded (mirrors
    CheckpointManager._gc on the training side).
    """

    IMAGE = "ckpt"

    def __init__(self, remote, local, image: str = IMAGE, keep: int = 2):
        self.remote = remote if isinstance(remote, LayerStore) \
            else LayerStore(str(remote))
        self.local = local if isinstance(local, LayerStore) \
            else LayerStore(str(local))
        self.image = image
        self.keep = keep
        self.last_step: Optional[int] = None
        self.last_pull: Optional[PushStats] = None

    def poll(self) -> Optional[Tuple[int, Any, Any]]:
        # lazy import: ckpt depends on core only, but keep serve->ckpt
        # out of module import time. The shared helpers guarantee the
        # replica and the trainer agree on tag format + retention.
        from ..ckpt.manager import latest_step, prune_steps, unflatten_tree
        # fresh: the trainer commits tags from another process/instance,
        # so the remote store's commit-point cache can't see them
        step = latest_step(self.remote, self.image, fresh=True)
        if step is None or step == self.last_step:
            return None
        tag = f"step-{step:08d}"
        self.last_pull = pull_delta(self.remote, self.local, self.image, tag)
        self.last_step = step
        # retention: drop superseded local checkpoints + sweep their blobs
        prune_steps(self.local, self.image, self.keep)
        flat = self.local.load_image_payload(self.image, tag)
        opt_flat = {k[len("opt/"):]: v for k, v in flat.items()
                    if k.startswith("opt/")}
        opt_flat.pop("__step__", None)
        params_flat = {k[len("params/"):]: v for k, v in flat.items()
                       if k.startswith("params/")}
        return step, unflatten_tree(params_flat), unflatten_tree(opt_flat)
