"""Batched serving engine: prefill once, decode step-by-step.

Single-host convenience wrapper over models.prefill / models.decode_step
(the production path jits the same functions through train.make_*_step with
mesh shardings — see launch/serve.py). Supports greedy and temperature
sampling, per-sequence stop tokens, and batched requests padded to a
common length.

``CheckpointFollower`` closes the §III.C redeployment loop for serving:
instead of re-downloading whole checkpoints, it pulls per-save DELTAS from
the training store (core.registry.pull_delta — one have-set negotiation,
only changed chunks over the wire, incremental verification) and the delta
stays sparse all the way into the model: ``poll`` compares the pulled
revision's records against the previous one (pure metadata — the stored
chunk lists name exactly which tensors moved), assembles ONLY the changed
tensors from the local store, and ``Engine.refresh(..., changed=...)``
device-puts only those leaves into the live param tree — replica refresh
cost is O(changed tensors), not O(model), and bit-identical to a full
reload (tests prove it). A structural change (tensor added/removed, shape
or dtype moved) falls back to the full reload automatically. With
``children=`` the follower doubles as a relay tier
(``core.registry.RelayNode``): every pulled delta re-fans to the
downstream edge stores through the same negotiated plan, streaming from
the in-flight pull by default.

The serving loop is also the last line of the self-healing blob universe
(ft/scrub.py + core.registry.repair_image): with ``verify=True`` (the
default) every pulled revision's consumed blobs are re-hashed BEFORE the
engine ever sees them; a corrupt revision triggers an in-line
anti-entropy repair from the followed remote, and if that cannot heal it
the poll returns None — the engine keeps serving the last-known-good
weights (``Engine.rollback`` covers the mid-swap failure case) instead
of crashing or serving torn tensors. ``FollowerHealth.corrupt_polls`` /
``EngineHealth.rollbacks`` surface both events to fleet controllers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Iterable, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (DeltaFormatError, LayerStore, PassiveRegistry,
                    PushRejected, PushStats, RelayNode, diff_tensor_records,
                    import_delta, plan_bundle_chain, repair_image,
                    replicate_fanout, sha256_hex)
from ..ft.faults import CrashInjected, fault_point
from ..ft.retry import RetryPolicy
from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, steps)
    logits_last: np.ndarray


def changed_tensor_paths(store: LayerStore, image: str, old_tag: str,
                         new_tag: str) -> Optional[Set[str]]:
    """The sparse-refresh plan between two tags a store holds: tensor
    names whose stored chunk lists differ (core.diff.diff_tensor_records —
    metadata only, no blob reads). None = structural change or unreadable
    base: caller must fall back to a full reload."""
    try:
        old_m, _ = store.read_image(image, old_tag)
        new_m, _ = store.read_image(image, new_tag)
        old_layers = [store.read_layer(lid) for lid in old_m.layer_ids]
        new_layers = [store.read_layer(lid) for lid in new_m.layer_ids]
    except (OSError, ValueError, KeyError):
        return None
    return diff_tensor_records(old_layers, new_layers)


@dataclass
class SparseUpdate:
    """One checkpoint transition as ``CheckpointFollower.poll`` returns
    it. Iterates as the historical ``(step, params, opt_state)`` triple;
    ``changed_params``/``changed_opt`` name the leaf paths that actually
    moved ('/'-joined, relative to each tree's root). ``None`` means a
    FULL update (first poll, or sparse fallback) — params/opt_state then
    hold the whole trees; otherwise they hold ONLY the changed leaves.
    Always consume as ``engine.refresh(upd.params, upd.changed_params)``
    (correct for both cases); a bare full swap of a sparse update's
    partial tree would drop the unchanged weights — callers that need the
    old whole-tree-every-poll behavior pass ``sparse=False`` to the
    follower."""

    step: int
    params: Any
    opt_state: Any
    changed_params: Optional[Set[str]] = None
    changed_opt: Optional[Set[str]] = None
    tensors_loaded: int = 0       # tensors assembled from the local store

    @property
    def full(self) -> bool:
        return self.changed_params is None

    def __iter__(self):
        yield from (self.step, self.params, self.opt_state)


@dataclass
class FollowerHealth:
    """Structured liveness snapshot of a ``CheckpointFollower`` — what a
    fleet controller reads to decide whether a replica is merely lagging
    (staleness grows, failures transient) or sick (consecutive failures
    climbing, same error repeating) and should be drained."""

    polls: int                      # poll() calls made
    failures: int                   # polls that raised
    consecutive_failures: int       # current unbroken failure run
    last_success_step: Optional[int]
    staleness_s: Optional[float]    # seconds since the last applied update
    retries_spent: int              # in-run retries the pull path consumed
    last_error: Optional[str]
    corrupt_polls: int = 0          # polls whose revision failed re-hash
    repairs: int = 0                # in-line repair_image heals attempted
    last_verify_error: Optional[str] = None   # why the last gate refused


@dataclass
class PassivePullStats:
    """Accounting for one passive (bundle-registry) pull: which chain the
    planner chose and what it actually cost. ``negotiations`` stays 0 on
    the passive path BY CONSTRUCTION — the plan comes entirely from the
    published index — and the bench counter-proves it."""

    hops: int = 0                   # bundle edges applied
    bytes_pulled: int = 0           # encoded bundle bytes fetched
    planned_bytes: int = 0          # the chain's ADVERTISED byte cost
    negotiations: int = 0           # have-set rounds (passive path: zero)
    edges_skipped: int = 0          # unusable edges dropped mid-pull
    fallback: str = ""              # "" | "remote" (smart pull took over)


@dataclass
class EngineHealth:
    """Snapshot of the serving engine's weight freshness: how many swaps
    have landed, what revision serves now, how long it has served."""

    refreshes: int
    last_refresh_leaves: int
    last_refresh_step: Optional[int]
    staleness_s: Optional[float]    # seconds since the last weight swap
    rollbacks: int = 0              # last-known-good restores performed
    last_rollback_step: Optional[int] = None  # step serving after the last one


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.last_refresh_leaves = 0
        self._refreshes = 0
        self._last_refresh_t: Optional[float] = None
        self._last_refresh_step: Optional[int] = None
        # last-known-good history (one level deep): the live tree is
        # stashed at the top of every refresh, so a swap that goes bad —
        # mid-refresh exception, or a revision rejected after the fact —
        # can be undone with rollback()
        self._prev_params: Optional[Any] = None
        self._prev_step: Optional[int] = None
        self._rollbacks = 0
        self._last_rollback_step: Optional[int] = None
        self._prefill = jax.jit(lambda p, t: prefill(cfg, p, t))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))

    def health(self) -> EngineHealth:
        return EngineHealth(
            refreshes=self._refreshes,
            last_refresh_leaves=self.last_refresh_leaves,
            last_refresh_step=self._last_refresh_step,
            staleness_s=None if self._last_refresh_t is None
            else time.monotonic() - self._last_refresh_t,
            rollbacks=self._rollbacks,
            last_rollback_step=self._last_rollback_step)

    def refresh(self, params, changed: Optional[Iterable[str]] = None,
                step: Optional[int] = None) -> int:
        """Hot-swap weights (e.g. from CheckpointFollower.poll). Params are
        a jit argument, so same-shape updates reuse the compiled
        prefill/decode executables — no retrace, no downtime.

        ``changed=None`` is the full swap: ``params`` replaces the whole
        tree. With ``changed`` (leaf paths, '/'-joined — a SparseUpdate's
        ``changed_params``), ``params`` need only hold those leaves: each
        one is device-put into a copy-on-write clone of the live tree
        (O(changed tensors) of H2D, the unchanged leaves stay resident and
        shared), which is bit-identical to a full reload of the same
        revision. Returns the number of leaves swapped in
        (``last_refresh_leaves`` keeps it for telemetry)."""
        # stash last-known-good BEFORE any mutation: the sparse path below
        # is copy-on-write (the stashed tree's spine is never aliased into
        # the new one), so rollback() after a mid-swap failure is always a
        # clean restore — and before the first assignment it is a no-op
        self._prev_params = self.params
        self._prev_step = self._last_refresh_step
        if changed is None:
            self.params = params
            self.last_refresh_leaves = len(jax.tree.leaves(params))
            self._stamp_refresh(step)
            return self.last_refresh_leaves
        root = dict(self.params)
        fresh = {id(root)}          # nodes already copied this refresh
        n = 0
        for path in sorted(set(changed)):
            node, parts = root, path.split("/")
            for p in parts[:-1]:
                nxt = node.get(p)
                if not isinstance(nxt, dict):
                    # a changed path whose parent isn't a subtree of the
                    # live tree is a broken sparse plan (stale changed set,
                    # restructured tree): grafting a new subtree would
                    # silently desync the pytree from the jitted signature
                    raise KeyError(
                        f"changed path {path!r}: {p!r} is not a subtree "
                        "of the live params (stale sparse plan? use a "
                        "full refresh)")
                if id(nxt) not in fresh:
                    nxt = dict(nxt)
                node[p] = nxt
                fresh.add(id(nxt))
                node = nxt
            if parts[-1] not in node:
                raise KeyError(
                    f"changed path {path!r} is not a leaf of the live "
                    "params (stale sparse plan? use a full refresh)")
            leaf = params
            for p in parts:
                leaf = leaf[p]
            node[parts[-1]] = jax.device_put(leaf)
            n += 1
        self.params = root
        self.last_refresh_leaves = n
        self._stamp_refresh(step)
        return n

    def rollback(self) -> bool:
        """Restore the param tree that served before the last ``refresh``
        — the last-known-good escape hatch a follower (or any caller)
        pulls when a swapped-in revision turns out corrupt or the swap
        itself died mid-flight. Bit-identical to the previous tree: the
        stash is the very object that was serving (sparse refreshes never
        mutate it — copy-on-write). History is deliberately one level
        deep; returns False when there is nothing to roll back to (fresh
        engine, or already rolled back)."""
        if self._prev_params is None:
            return False
        self.params, self._prev_params = self._prev_params, None
        self._last_refresh_step, self._prev_step = self._prev_step, None
        self._rollbacks += 1
        self._last_rollback_step = self._last_refresh_step
        return True

    def _stamp_refresh(self, step: Optional[int]) -> None:
        self._refreshes += 1
        self._last_refresh_t = time.monotonic()
        if step is not None:
            self._last_refresh_step = step

    def generate(self, prompts: np.ndarray, steps: int,
                 temperature: float = 0.0, seed: int = 0,
                 stop_token: Optional[int] = None) -> GenerationResult:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for fixed-length synthetic prompts)."""
        B, S = prompts.shape
        assert S + steps <= self.max_len or self.cfg.window, \
            "prompt + steps exceeds cache"
        cache = init_cache(self.cfg, B, self.max_len)
        # prefill builds a cache sized cache_len(S); splice it into the
        # full-size decode cache ring-consistently
        pf_cache, logits = self._prefill(self.params, jnp.asarray(prompts))
        cache = self._splice(cache, pf_cache, S)
        key = jax.random.PRNGKey(seed)
        out = np.zeros((B, steps), np.int32)
        logits_np = None
        tok = self._sample(logits, temperature, key)
        for i in range(steps):
            out[:, i] = np.asarray(tok)
            cache, logits = self._decode(self.params, cache, tok,
                                         jnp.int32(S + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
            if stop_token is not None and bool((out[:, i] == stop_token).all()):
                out = out[:, :i + 1]
                break
        logits_np = np.asarray(logits)
        return GenerationResult(tokens=out, logits_last=logits_np)

    def _sample(self, logits, temperature: float, key):
        logits = logits[..., :self.cfg.vocab]
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1) \
            .astype(jnp.int32)

    def _splice(self, cache, pf_cache, S: int):
        """Insert prefill cache (length C_pf, ring layout) into the decode
        cache (length C_full) preserving slot = pos % C semantics."""
        def one(full, pf):
            if full.shape == pf.shape:
                return pf            # ssm states / same-length caches
            C_full, C_pf = full.shape[2], pf.shape[2]
            # prefill ring holds positions S-C_pf..S-1 at slot pos % C_pf;
            # unroll to chronological then place at pos % C_full.
            start = S - C_pf
            idx = (start + np.arange(C_pf)) % C_pf        # chronological
            chron = jnp.take(pf, jnp.asarray(idx), axis=2)
            slots = (start + np.arange(C_pf)) % C_full
            return full.at[:, :, jnp.asarray(slots)].set(chron)
        return jax.tree.map(one, cache, pf_cache)


class CheckpointFollower:
    """Keep a serving store in sync with a training store by pulling
    per-save deltas (see module docstring).

    ``remote`` is the training-side LayerStore (or its path); ``local`` is
    this server's store. ``poll()`` pulls any checkpoint newer than the
    last one seen — O(changed bytes) on the wire — and returns a
    ``SparseUpdate`` (iterates as the historical (step, params, opt_state)
    triple) ready for ``Engine.refresh``, or None when already up to date.
    With ``sparse`` (the default) every poll after the first assembles
    ONLY the tensors whose records changed between the previous and the
    pulled revision — O(changed tensors) of local blob reads — and names
    them in ``changed_params``/``changed_opt`` so the engine can
    device-put just those leaves; structural changes fall back to a full
    load. The local store keeps the ``keep`` newest checkpoints and
    mark-and-sweeps the rest after each pull, so a long-running replica's
    disk stays bounded (mirrors CheckpointManager._gc on the training
    side).

    ``image=`` names the followed image, and the local store may be
    SHARED by several followers (one per tenant) and by a pre-seeded base
    image: the pull negotiates against the local store's whole committed
    namespace (cross-image holdings), so the first poll of a fresh
    fine-tune over a base-holding store transfers only the adapter delta,
    and retention is cross-image safe — ``prune_steps`` removes only THIS
    image's stale step tags, and the store-wide ``gc()`` it triggers
    never sweeps a blob any sibling image (or lease) still reaches.

    ``children`` turns this follower into a RELAY: each poll pulls the
    delta once from the trainer and re-fans it to the downstream stores
    (edge tier) through the same negotiated plan — streaming from the
    in-flight pull by default (``source="inflight"``), with every child's
    commit gated on the local commit. Child outcomes land in ``last_fan``
    (per-child failure isolation; a sick edge never blocks this replica's
    own refresh, and the next poll's re-fan converges it). Every child
    store shares this follower's ``keep`` retention, so edge disks stay
    bounded too.

    Retention races are survived, not raised: a trainer that prunes the
    tag mid-pull makes ``poll`` return None (the next poll sees a newer
    tag), and a pruned-away base revision just downgrades the sparse plan
    to a full update.
    """

    IMAGE = "ckpt"

    def __init__(self, remote, local, image: str = IMAGE, keep: int = 2,
                 sparse: bool = True, children: Sequence = (),
                 source: str = "inflight",
                 retry: Optional[RetryPolicy] = None,
                 verify: bool = True,
                 registry=None):
        if remote is None and registry is None:
            raise ValueError("follower needs a remote store, a passive "
                             "registry, or both")
        self.remote = None if remote is None else (
            remote if isinstance(remote, LayerStore)
            else LayerStore(str(remote)))
        # passive bundle registry (a PassiveRegistry, or a directory path /
        # http(s) URL): polls plan the cheapest published chain from its
        # signed index — zero negotiation round-trips — and only fall back
        # to the smart ``remote`` pull when no advertised chain works.
        # remote=None makes the follower FULLY passive: it can serve from a
        # dumb file/object store with no training-side endpoint at all.
        self.registry = registry if registry is None or \
            isinstance(registry, PassiveRegistry) \
            else PassiveRegistry(str(registry))
        self.local = local if isinstance(local, LayerStore) \
            else LayerStore(str(local))
        self.relay = RelayNode(self.local, children=children,
                               source=source, retry=retry) if children \
            else None
        self.image = image
        self.keep = keep
        self.sparse = sparse
        self.retry = retry            # in-run self-healing for the pull
        self.verify = verify          # re-hash every revision pre-swap
        self.last_step: Optional[int] = None
        self.last_pull: Optional[PushStats] = None
        self.last_plan: Optional[PassivePullStats] = None
        self.last_update: Optional[SparseUpdate] = None
        self.last_fan = None          # child-tier FanoutStats (relay mode)
        self._polls = 0
        self._failures = 0
        self._consecutive_failures = 0
        self._retries_spent = 0
        self._last_success_t: Optional[float] = None
        self._last_error: Optional[str] = None
        self._corrupt_polls = 0
        self._repairs = 0
        self.last_verify_error: Optional[str] = None

    def health(self) -> FollowerHealth:
        """Structured snapshot for fleet controllers: staleness is seconds
        since the last APPLIED update (None before the first), consecutive
        failures reset on any clean poll — including an up-to-date None."""
        return FollowerHealth(
            polls=self._polls, failures=self._failures,
            consecutive_failures=self._consecutive_failures,
            last_success_step=self.last_step,
            staleness_s=None if self._last_success_t is None
            else time.monotonic() - self._last_success_t,
            retries_spent=self._retries_spent,
            last_error=self._last_error,
            corrupt_polls=self._corrupt_polls,
            repairs=self._repairs,
            last_verify_error=self.last_verify_error)

    def _pull(self, tag: str) -> Optional[PushStats]:
        """One delta pull (re-fanned to children in relay mode), hardened
        against the retention race: if the trainer pruned ``tag`` between
        ``latest_step`` and the pull, give up quietly — the next poll sees
        a newer tag. Anything that fails while the remote still HAS the
        tag is a real error and re-raises (after ``retry`` converged or
        quarantined, when one is configured)."""
        try:
            fault_point("follower.pull",
                        f"{self.local.root}:{self.image}:{tag}")
            fan = replicate_fanout(self.remote,
                                   [self.relay or self.local],
                                   self.image, tag, retry=self.retry)
            self._retries_spent += fan.retries_spent
            rep = fan.replicas[0]
            if rep.exception is not None:
                raise rep.exception
            if self.relay is not None:
                self.last_fan = rep.children
            return rep.stats
        except (OSError, PushRejected):
            if self.remote.has_image(self.image, tag):
                raise
            return None

    def _read_index(self):
        """The registry's signed index, or None when it is missing,
        unreachable, truncated or fails its signature — an unusable
        advertisement is a reason to fall back, never a poll error."""
        if self.registry is None:
            return None
        try:
            return self.registry.read_index(self.image)
        except (OSError, ConnectionError, ValueError):
            return None

    def _pull_passive(self, index, tag: str) -> Optional[PushStats]:
        """Reach ``tag`` by applying published bundles along the cheapest
        advertised chain — zero negotiation round-trips (the plan comes
        entirely from the index; ``import_delta`` on a plain store never
        calls ``negotiate``). Every hop is verified against the index's
        size + sha256 and re-verified content-addressed on receipt; an
        edge that fails ANY of that — fetch error, hash mismatch, a
        bundle whose endpoint tags the publisher or this store pruned —
        is skipped and the chain replanned without it, never raised.
        Returns None when no advertised chain can reach ``tag`` (the
        caller falls back to the smart remote pull, when there is one)."""
        plan_stats = PassivePullStats()
        self.last_plan = plan_stats
        held = set(self.local.list_tags(self.image, fresh=True))
        skip: Set = set()
        agg: Optional[PushStats] = None
        while True:
            plan = plan_bundle_chain(index, held, head=tag, skip=skip)
            if plan is None:
                return None
            if not plan:
                break
            entry = plan[0]
            try:
                data = self.registry.fetch_bundle(self.image, entry)
                stats = import_delta(self.relay or self.local, data)
            except (ConnectionError, OSError, PushRejected, ValueError,
                    KeyError):
                skip.add((entry.from_tag, entry.to_tag))
                plan_stats.edges_skipped += 1
                continue
            plan_stats.hops += 1
            plan_stats.bytes_pulled += len(data)
            plan_stats.planned_bytes += entry.size
            held.add(entry.to_tag)
            if agg is None:
                agg = stats
            else:
                for f in ("blobs_sent", "blobs_dedup", "layers_sent",
                          "layers_dedup", "bytes_sent", "bytes_payload",
                          "bytes_meta", "bytes_deduped",
                          "layers_deep_verified", "layers_rekey_verified",
                          "blobs_hashed_remote"):
                    setattr(agg, f, getattr(agg, f) + getattr(stats, f))
                agg.wall_s += stats.wall_s
        return agg if agg is not None else PushStats()

    def poll(self) -> Optional[SparseUpdate]:
        """Health-instrumented wrapper over the sync step: failures are
        COUNTED (consecutive run + last error) before re-raising, so a
        crashing poll leaves a readable record; see ``health()``."""
        self._polls += 1
        try:
            upd = self._poll_inner()
        except Exception as e:  # noqa: BLE001
            self._failures += 1
            self._consecutive_failures += 1
            self._last_error = f"{type(e).__name__}: {e}"
            raise
        self._consecutive_failures = 0
        self._last_error = None
        if upd is not None:
            self._last_success_t = time.monotonic()
        return upd

    def _poll_inner(self) -> Optional[SparseUpdate]:
        # lazy import: ckpt depends on core only, but keep serve->ckpt
        # out of module import time. The shared helpers guarantee the
        # replica and the trainer agree on tag format + retention.
        from ..ckpt.manager import (latest_step, prune_steps, step_of_tag,
                                    unflatten_tree)
        # head discovery: the signed bundle index (passive) and/or the
        # remote's tag listing (smart). A stale index can trail the
        # trainer, so with both available the newer head wins; fresh=True
        # on the remote because the trainer commits tags from another
        # process/instance, invisible to its commit-point cache.
        index = self._read_index()
        passive_step = None if index is None else step_of_tag(index.head)
        remote_step = None if self.remote is None else \
            latest_step(self.remote, self.image, fresh=True)
        step = max((s for s in (passive_step, remote_step) if s is not None),
                   default=None)
        if step is None or \
                (self.last_step is not None and step <= self.last_step):
            return None
        tag = f"step-{step:08d}"
        pulled = None
        if index is not None and passive_step == step:
            pulled = self._pull_passive(index, tag)
            if pulled is None and self.last_plan is not None and \
                    self.remote is not None:
                self.last_plan.fallback = "remote"
        if pulled is None and self.remote is not None:
            pulled = self._pull(tag)
        if pulled is None:           # tag pruned mid-pull / no usable
            return None              # chain: retry next poll
        self.last_pull = pulled
        # sparse plan BEFORE retention prunes the previous tag away
        changed: Optional[Set[str]] = None
        if self.sparse and self.last_step is not None:
            prev_tag = f"step-{self.last_step:08d}"
            changed = changed_tensor_paths(self.local, self.image,
                                           prev_tag, tag)
        # verify gate: re-hash exactly the blobs this refresh will consume
        # BEFORE assembling tensors from them. A corrupt revision (at-rest
        # bit-rot, a persisted torn write) gets one in-line anti-entropy
        # heal from the followed remote; if that cannot produce a clean
        # revision the poll returns None WITHOUT advancing last_step — the
        # engine keeps serving last-known-good weights and the next poll
        # retries the same tag against a possibly-healthier remote.
        if self.verify:
            bad = self._verify_revision(tag, changed)
            if bad:
                self._corrupt_polls += 1
                if self._repair_revision(tag):
                    bad = self._verify_revision(tag, changed)
            if bad:
                self.last_verify_error = (
                    f"{tag}: {bad[0]}" +
                    (f" (+{len(bad) - 1} more)" if len(bad) > 1 else ""))
                return None
        flat = self.local.load_image_payload(
            self.image, tag, names=None if changed is None else changed)
        self.last_step = step
        # retention: drop superseded local checkpoints + sweep their blobs
        # — at EVERY tier this follower feeds, or the edge stores would
        # accumulate one committed step per poll forever
        prune_steps(self.local, self.image, self.keep)
        if self.relay is not None:
            for s in self.relay.all_stores():
                if s is not self.local:
                    prune_steps(s, self.image, self.keep)
        opt_flat = {k[len("opt/"):]: v for k, v in flat.items()
                    if k.startswith("opt/")}
        opt_flat.pop("__step__", None)
        params_flat = {k[len("params/"):]: v for k, v in flat.items()
                       if k.startswith("params/")}
        self.last_update = SparseUpdate(
            step=step,
            params=unflatten_tree(params_flat),
            opt_state=unflatten_tree(opt_flat),
            changed_params=None if changed is None else
            {k[len("params/"):] for k in changed
             if k.startswith("params/")},
            changed_opt=None if changed is None else
            {k[len("opt/"):] for k in changed
             if k.startswith("opt/") and k != "opt/__step__"},
            tensors_loaded=len(flat),
        )
        return self.last_update

    def _verify_revision(self, tag: str,
                         changed: Optional[Set[str]]) -> List[str]:
        """Re-hash the local blobs the coming refresh will consume —
        scoped to the sparse plan's changed tensors when there is one (the
        unchanged leaves already serve from device memory; their disk
        state is the background scrub's business, not this hot path's).
        Returns human-readable problems, empty = clean."""
        st = self.local
        problems: List[str] = []
        try:
            manifest, _ = st.read_image(self.image, tag)
            for lid in manifest.layer_ids:
                layer = st.read_layer(lid, use_cache=False)
                for rec in layer.records:
                    if changed is not None and rec.name not in changed:
                        continue
                    for h in rec.chunks:
                        try:
                            if sha256_hex(st.read_blob(h)) != h:
                                problems.append(
                                    f"corrupt blob {h[:12]} ({rec.name})")
                        except OSError:
                            problems.append(
                                f"missing blob {h[:12]} ({rec.name})")
        except (OSError, ValueError, KeyError) as e:
            problems.append(f"revision metadata unreadable: {e}")
        return problems

    def _repair_revision(self, tag: str) -> bool:
        """One in-line anti-entropy heal of a corrupt pulled revision from
        the followed remote (core.registry.repair_image: quarantine the
        bad blobs, pull only the damaged bytes, deep-verify). True = the
        revision is clean again and the poll may proceed. A fully passive
        follower (no remote) has no live peer to heal from — it refuses
        the revision and keeps serving last-known-good."""
        if self.remote is None:
            self.last_verify_error = \
                f"repair of {tag} skipped: no remote peer"
            return False
        try:
            rep = repair_image(self.local, self.image, tag,
                               peers=[self.remote])
        except CrashInjected:
            raise           # the follower process dying mid-repair must
            # surface from poll(), not read as "repair failed, refused"
        except Exception as e:  # noqa: BLE001
            self.last_verify_error = \
                f"repair of {tag} failed: {type(e).__name__}: {e}"
            return False
        self._repairs += 1
        return rep.verified_clean

    def poll_and_refresh(self, engine: Engine) -> Optional[SparseUpdate]:
        """Closed-loop sync: poll once and hot-swap ``engine``, never
        letting a bad revision take the server down. A wire fault
        (``ConnectionError`` — which injected chaos faults subclass) is
        swallowed: the engine keeps serving its current weights and the
        next call retries. A refresh that dies mid-swap rolls the engine
        back to the previous committed params (``Engine.rollback``)
        instead of leaving a torn tree. Returns the applied update, or
        None when nothing changed or nothing could be SAFELY applied
        (``health()`` tells the two apart)."""
        try:
            upd = self.poll()
        except ConnectionError:
            return None               # counted by poll(); serve stale
        if upd is None:
            return None
        try:
            engine.refresh(upd.params, upd.changed_params, step=upd.step)
        except Exception as e:  # noqa: BLE001
            engine.rollback()
            self.last_verify_error = \
                f"refresh rolled back: {type(e).__name__}: {e}"
            return None
        return upd
