from .engine import Engine, GenerationResult

__all__ = ["Engine", "GenerationResult"]
