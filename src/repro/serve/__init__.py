from .engine import (CheckpointFollower, Engine, EngineHealth,
                     FollowerHealth, GenerationResult, SparseUpdate,
                     changed_tensor_paths)

__all__ = ["CheckpointFollower", "Engine", "EngineHealth",
           "FollowerHealth", "GenerationResult", "SparseUpdate",
           "changed_tensor_paths"]
