from .engine import CheckpointFollower, Engine, GenerationResult

__all__ = ["CheckpointFollower", "Engine", "GenerationResult"]
