from .engine import (CheckpointFollower, Engine, GenerationResult,
                     SparseUpdate, changed_tensor_paths)

__all__ = ["CheckpointFollower", "Engine", "GenerationResult",
           "SparseUpdate", "changed_tensor_paths"]
