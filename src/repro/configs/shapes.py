"""Assigned input shapes and abstract input specs for the dry-run.

LM transformer shapes are seq_len x global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token with a KV cache of seq_len), NOT
``train_step``. ``long_500k`` runs only for sub-quadratic archs (SSM /
hybrid / SWA) — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp

from ..models import init_cache
from ..models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        specs = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
            "mask": _sds((B, S), jnp.float32),
        }
        if cfg.n_prefix_embeds:
            specs["prefix_embeds"] = _sds(
                (B, cfg.n_prefix_embeds, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return specs
    if sp.kind == "prefill":
        specs = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.n_prefix_embeds:
            specs["prefix_embeds"] = _sds(
                (B, cfg.n_prefix_embeds, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return specs
    if sp.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
        return {
            "cache": cache,
            "tokens": _sds((B,), jnp.int32),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(sp.kind)
