"""minicpm3-4b [dense] — MLA (multi-head latent attention).

62L d_model=2560 40H d_ff=6400 vocab=73448 [hf:openbmb/MiniCPM3-4B; hf].
MLA dims from the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope=64, qk_rope=32, v_head=64. The assignment's "kv=40" reflects the
MHA-equivalent head count; MLA caches the 256+32 latent instead.
"""
from ..models.config import ModelConfig

ARCH_ID = "minicpm3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="mla",
        n_layers=62, d_model=2560, vocab=73448,
        n_heads=40, n_kv_heads=40, head_dim=64,
        q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        d_ff=6400, act="swiglu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=4, head_dim=16, q_lora_rank=32,
                            kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
                            v_head_dim=16, d_ff=128)
