"""pixtral-12b [vlm] — Pixtral-ViT frontend (STUB) + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8) head_dim=128 d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]. The vision frontend supplies
precomputed patch embeddings via ``prefix_embeds`` per the assignment.
"""
from ..models.config import ModelConfig

ARCH_ID = "pixtral-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=5120, vocab=131072,
        n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, act="swiglu", rope_theta=1e6,
        frontend="vision", n_prefix_embeds=256,
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=128,
                            n_prefix_embeds=4)
