"""mamba2-130m [ssm] — attention-free SSD (state-space duality).

24L d_model=768 d_inner=1536 heads=24 headdim=64 ssm_state=128 vocab=50280
[arXiv:2405.21060; unverified].
"""
from ..models.config import ModelConfig

ARCH_ID = "mamba2-130m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=768, vocab=50280,
        d_inner=1536, ssm_state=128, ssm_heads=24, ssm_groups=1,
        conv_kernel=4, ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, d_inner=128,
                            ssm_state=16, ssm_heads=4)
