"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) head_dim=128 expert d_ff=14336 vocab=32000
window=4096 [arXiv:2401.04088; hf].
"""
from ..models.config import ModelConfig

ARCH_ID = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=4096, vocab=32000,
        n_heads=32, n_kv_heads=8, head_dim=128,
        n_experts=8, top_k=2, d_ff_expert=14336, d_ff=0,
        window=4096, rope_theta=1e6, act="swiglu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=2, head_dim=16, n_experts=4, top_k=2,
                            d_ff_expert=64, window=16)
