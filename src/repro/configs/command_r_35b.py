"""command-r-35b [dense] — GQA, no-bias.

40L d_model=8192 64H (GQA kv=8) head_dim=128 d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]. rope_theta=8M per HF config.
"""
from ..models.config import ModelConfig

ARCH_ID = "command-r-35b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=8192, vocab=256000,
        n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22528, act="swiglu", rope_theta=8e6,
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=8,
                            n_kv_heads=2, head_dim=16, d_ff=128)
