"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from typing import List

from ..models.config import ModelConfig
from . import (command_r_35b, gemma_2b, granite_moe_3b, hymba_1_5b,
               mamba2_130m, minicpm3_4b, mixtral_8x7b, musicgen_medium,
               pixtral_12b, yi_6b)

_MODULES = {
    m.ARCH_ID: m
    for m in (pixtral_12b, mamba2_130m, granite_moe_3b, mixtral_8x7b,
              gemma_2b, command_r_35b, minicpm3_4b, yi_6b,
              musicgen_medium, hymba_1_5b)
}

ARCH_IDS: List[str] = list(_MODULES)


def list_archs() -> List[str]:
    return list(ARCH_IDS)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    return _MODULES[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke_config()
