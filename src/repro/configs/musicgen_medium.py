"""musicgen-medium [audio] — decoder-only LM over EnCodec tokens.

48L d_model=1536 24H (kv=24, MHA) head_dim=64 d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec frontend is a STUB: the backbone
consumes token ids directly (codebook interleaving is a frontend concern);
positional scheme mapped to RoPE (orthogonal to all experiments here —
see DESIGN.md).
"""
from ..models.config import ModelConfig

ARCH_ID = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=48, d_model=1536, vocab=2048,
        n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, act="swiglu",
        frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=4, head_dim=16, d_ff=128)
