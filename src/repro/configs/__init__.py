from .registry import ARCH_IDS, get_config, get_smoke_config, list_archs
from .shapes import SHAPES, ShapeSpec, applicable_shapes, input_specs

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "list_archs",
           "SHAPES", "ShapeSpec", "applicable_shapes", "input_specs"]
