"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block.

32L d_model=1600 25H (GQA kv=5) head_dim=64 d_ff=5504 vocab=32001
ssm_state=16 [arXiv:2411.13676; hf]. SWA window=2048 on the attention path
(the paper's global-attention layers and meta tokens are omitted — see
DESIGN.md); SSD heads: d_inner=1600, 25 heads, headdim 64.
"""
from ..models.config import ModelConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=32, d_model=1600, vocab=32001,
        n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, act="swiglu", window=2048,
        d_inner=1600, ssm_state=16, ssm_heads=25, ssm_groups=1,
        conv_kernel=4, ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=5,
                            n_kv_heads=1, head_dim=16, d_ff=128, window=16,
                            d_inner=80, ssm_state=8, ssm_heads=5)
