"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) head_dim=64 expert d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-*-base family; hf].
"""
from ..models.config import ModelConfig

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=1536, vocab=49155,
        n_heads=24, n_kv_heads=8, head_dim=64,
        n_experts=40, top_k=8, d_ff_expert=512, d_ff=0,
        act="swiglu",
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=2, head_dim=16, n_experts=8, top_k=2,
                            d_ff_expert=32)
