"""yi-6b [dense] — llama-arch GQA.

32L d_model=4096 32H (GQA kv=4) head_dim=128 d_ff=11008 vocab=64000
[arXiv:2403.04652; hf]. rope_theta=5M per HF config.
"""
from ..models.config import ModelConfig

ARCH_ID = "yi-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=4096, vocab=64000,
        n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, act="swiglu", rope_theta=5e6,
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=2, head_dim=16, d_ff=128)
