"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1), tied embeddings.

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000 [arXiv:2403.08295; hf].
"""
from ..models.config import ModelConfig

ARCH_ID = "gemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=18, d_model=2048, vocab=256000,
        n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, act="geglu",
        tie_embeddings=True, embed_scale=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(n_layers=2, d_model=64, vocab=199, n_heads=4,
                            n_kv_heads=1, head_dim=16, d_ff=128)
