"""Step factories: build sharded, jitted train / prefill / decode steps.

The factory resolves the sharding recipe for (arch, shape-kind, mesh),
computes PartitionSpecs for params / optimizer / batch / cache, installs
the activation-rule context at trace time, and returns the jitted function
plus its shardings (the dry-run lowers the same object the trainer runs).

Features:
* microbatch gradient accumulation (lax.scan over microbatches)
* remat policy from ModelConfig
* ZeRO-1 optimizer sharding over the DP axes
* optional int8+error-feedback compressed gradient all-reduce (shard_map
  over DP) for the "dp" recipe
* cache donation on decode (in-place KV update)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import (decode_step as model_decode, init_cache, init_params,
                      loss_fn, prefill as model_prefill)
from ..models.config import ModelConfig
from ..optim import AdamWConfig, apply_update, init_opt_state
from ..sharding.ctx import activation_ctx
from ..sharding.rules import (Recipe, activation_rules, batch_specs,
                              cache_specs, opt_specs, param_specs_tree,
                              recipe_for, zero_axes_for)


@dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    microbatches: int = 0       # 0 = auto: target ~2 samples/device/microbatch
    zero1: bool = True
    grad_compression: Optional[str] = None     # None | "int8_ef"
    grad_reduce_dtype: Optional[str] = None    # e.g. "bfloat16": cast the
                                               # accumulated grads before the
                                               # cross-replica reduction
    recipe: Optional[str] = None               # override recipe name


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


@dataclass
class StepBundle:
    """A compiled-step package: fn + shardings (dry-run lowers fn too)."""
    fn: Any
    in_shardings: Any
    out_shardings: Any
    recipe: Recipe
    abstract_inputs: Any = None


# ------------------------------------------------------------------ train
def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                    global_batch: int, seq_len: int) -> StepBundle:
    recipe = recipe_for(cfg, "train", mesh)
    if tcfg.recipe:
        recipe = Recipe(tcfg.recipe, "train")
    pshape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspec = param_specs_tree(cfg, recipe, mesh, pshape)
    oshape = jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), pshape)))
    zero_axes = zero_axes_for(recipe, mesh) if tcfg.zero1 else ()
    ospec = {
        "step": P(),
        "master": opt_specs(pspec, pshape, mesh, zero_axes),
        "m": opt_specs(pspec, pshape, mesh, zero_axes),
        "v": opt_specs(pspec, pshape, mesh, zero_axes),
    }
    bspec = batch_specs(cfg, recipe, mesh, global_batch)
    arules = activation_rules(cfg, recipe, mesh, global_batch)
    nmicro = tcfg.microbatches
    if nmicro == 0:
        # auto: per-device microbatch of ~2 samples bounds saved activations
        baxes = bspec["tokens"][0] or ()
        dp_size = 1
        for a in (baxes if isinstance(baxes, tuple) else (baxes,)):
            dp_size *= mesh.shape[a]
        per_dev = max(1, global_batch // dp_size)
        nmicro = max(1, per_dev // 2)
        while global_batch % (nmicro * dp_size) and nmicro > 1:
            nmicro -= 1

    def step(params, opt_state, batch):
        with activation_ctx(arules):
            if nmicro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            else:
                def micro(carry, mb):
                    acc, _ = carry
                    (l, m), g = jax.value_and_grad(
                        lambda p: loss_fn(cfg, p, mb),
                        has_aux=True)(params)
                    return (jax.tree.map(jnp.add, acc, g), l), m

                mbs = jax.tree.map(
                    lambda a: a.reshape((nmicro, a.shape[0] // nmicro)
                                        + a.shape[1:]), batch)
                zero_g = jax.tree.map(
                    lambda a: jnp.zeros(a.shape, jnp.float32), params)
                (grads, loss), metrics = jax.lax.scan(
                    micro, (zero_g, jnp.float32(0)), mbs)
                grads = jax.tree.map(lambda g: g / nmicro, grads)
                metrics = jax.tree.map(lambda a: a[-1], metrics)
            if tcfg.grad_reduce_dtype is not None:
                rd = jnp.dtype(tcfg.grad_reduce_dtype)
                grads = jax.tree.map(lambda g: g.astype(rd), grads)
            new_params, new_opt, stats = apply_update(
                tcfg.adamw, params, opt_state, grads)
            out_metrics = {"loss": loss, **metrics, **stats}
        return new_params, new_opt, out_metrics

    in_sh = (_named(mesh, pspec), _named(mesh, ospec),
             {k: NamedSharding(mesh, s) for k, s in bspec.items()})
    out_sh = (_named(mesh, pspec), _named(mesh, ospec), None)
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    return StepBundle(fn=fn, in_shardings=in_sh, out_shardings=out_sh,
                      recipe=recipe,
                      abstract_inputs=(pshape, oshape, None))


# ---------------------------------------------------------------- prefill
def make_prefill_step(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                      seq_len: int, recipe_name: Optional[str] = None
                      ) -> StepBundle:
    recipe = recipe_for(cfg, "prefill", mesh)
    if recipe_name:
        recipe = Recipe(recipe_name, "prefill")
    pshape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspec = param_specs_tree(cfg, recipe, mesh, pshape)
    bspec = batch_specs(cfg, recipe, mesh, global_batch)
    arules = activation_rules(cfg, recipe, mesh, global_batch)
    cshape = jax.eval_shape(
        lambda: init_cache(cfg, global_batch, seq_len))
    cspec = cache_specs(cfg, Recipe("decode", "decode"), mesh,
                        global_batch, cshape)

    def step(params, tokens, prefix_embeds=None):
        with activation_ctx(arules):
            cache, logits = model_prefill(cfg, params, tokens,
                                          prefix_embeds)
        return cache, logits

    in_sh = [_named(mesh, pspec), NamedSharding(mesh, bspec["tokens"])]
    if cfg.n_prefix_embeds:
        in_sh.append(NamedSharding(mesh, bspec["prefix_embeds"]))
    out_sh = (_named(mesh, cspec), None)
    fn = jax.jit(step, in_shardings=tuple(in_sh), out_shardings=out_sh)
    return StepBundle(fn=fn, in_shardings=tuple(in_sh), out_shardings=out_sh,
                      recipe=recipe, abstract_inputs=(pshape,))


# ----------------------------------------------------------------- decode
def make_decode_step(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                     cache_len: int, recipe_name: Optional[str] = None
                     ) -> StepBundle:
    recipe = Recipe(recipe_name or "decode", "decode")
    pshape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    # decode params follow the prefill/train recipe for weight placement
    wrecipe = recipe_for(cfg, "train", mesh)
    pspec = param_specs_tree(cfg, wrecipe, mesh, pshape)
    cshape = jax.eval_shape(lambda: init_cache(cfg, global_batch, cache_len))
    cspec = cache_specs(cfg, recipe, mesh, global_batch, cshape)
    arules = activation_rules(cfg, recipe, mesh, global_batch)
    baxes = batch_specs(cfg, recipe, mesh, global_batch)

    def step(params, cache, tokens, pos):
        with activation_ctx(arules):
            cache, logits = model_decode(cfg, params, cache, tokens, pos)
        return cache, logits

    in_sh = (_named(mesh, pspec), _named(mesh, cspec),
             NamedSharding(mesh, P(baxes["tokens"][0])),
             NamedSharding(mesh, P()))
    out_sh = (_named(mesh, cspec), None)
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(1,))
    return StepBundle(fn=fn, in_shardings=in_sh, out_shardings=out_sh,
                      recipe=recipe, abstract_inputs=(pshape, cshape))
