from .analyze import (HW, CellResult, analyze_compiled, collective_bytes,
                      roofline_terms)

__all__ = ["HW", "CellResult", "analyze_compiled", "collective_bytes",
           "roofline_terms"]
