"""Trip-count-aware static analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
program built from lax.scan (layer stacks, microbatches, KV blocks — i.e.
everything here) is undercounted by orders of magnitude. This module
re-derives per-device totals by walking the computation graph from ENTRY
and multiplying while bodies by their trip counts:

* FLOPs        — from ``dot`` ops (2 x prod(result) x prod(contraction));
                 elementwise flops are ignored (<1% for transformer work).
* HBM traffic  — fusion-level model: every materialized instruction reads
                 its operands and writes its result once per execution
                 (parameters/constants/GTE/tuple/bitcast move nothing).
                 This is the standard post-fusion roofline traffic model;
                 it ignores cache hits (upper bound on traffic).
* collectives  — result bytes per op kind, all-reduce counted 2x (ring).

Trip counts come from the while condition's comparison constant — exact for
lax.scan-generated loops (induction starts at 0, compares LT length).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ARRAY_TYPE_RE = re.compile(r"^[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*")
_OP_RE = re.compile(r"^\s*([a-zA-Z0-9\-_\$]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _parse_instr_line(line: str):
    """-> (name, rtype, op, rest) or None. Handles tuple types with
    nested parens and layout braces via balanced-paren scanning."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rtype = rest[:i + 1]
                    rest = rest[i + 1:]
                    break
        else:
            return None
    else:
        mt = _ARRAY_TYPE_RE.match(rest)
        if not mt:
            return None
        rtype = mt.group(0)
        rest = rest[mt.end():]
    mo = _OP_RE.match(rest)
    if not mo:
        return None
    return name, rtype, mo.group(1), rest[mo.end():]


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    rtype: str
    op: str
    rest: str                    # operand list + attributes (raw)

    @property
    def operands(self) -> List[str]:
        # operands appear before the closing paren of the op call; attr
        # text also contains %refs (to_apply etc) — split at first '), '
        depth, end = 0, len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return re.findall(r"%[\w\.\-]+", self.rest[:end])

    def ref(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=(%[\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def dims(self, key: str) -> List[int]:
        m = re.search(rf"{key}={{([0-9,]*)}}", self.rest)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # %name -> type


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            ins = Instr(*parsed)
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.rtype
    return comps, entry


_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota"}
_CALL_OPS = {"while", "call", "conditional", "fusion", "async-start"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def coll_wire_bytes(self) -> float:
        total = 0.0
        for k, v in self.coll.items():
            total += v * (2.0 if k.startswith("all-reduce") else 1.0)
        return total


def _trip_count(cond: Computation) -> int:
    """lax.scan conditions compare the induction var against a constant."""
    consts = []
    for ins in cond.instrs:
        if ins.op == "constant" and ins.rtype.startswith("s32"):
            m = re.match(r"(-?\d+)\)", ins.rest)
            if m:
                consts.append(int(m.group(1)))
    big = [c for c in consts if c > 0]
    return max(big) if big else 1


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    ops = ins.operands
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    sd = _shape_dims(lhs_type)
    if not sd:
        return 0.0
    lhs_dims = sd[0][1]
    contract = ins.dims("lhs_contracting_dims")
    csize = 1
    for c in contract:
        if c < len(lhs_dims):
            csize *= lhs_dims[c]
    rsize = 1
    for _, dims in _shape_dims(ins.rtype):
        for d in dims:
            rsize *= d
    return 2.0 * rsize * csize


def _fusion_traffic(fused: Computation, call: Instr,
                    caller_shapes: Dict[str, str]) -> float:
    """HBM traffic of one fusion execution (reads + writes).

    Two special patterns XLA relies on:
    * slice-only parameters (scan reading one layer of stacked weights):
      only the slice bytes move;
    * in-place dynamic-update-slice fusions (scan writing one layer of a
      stacked residual buffer): only the update region moves — the
      pass-through region is aliased, NOT copied.
    """
    dus = [i2 for i2 in fused.instrs if i2.op == "dynamic-update-slice"]
    if dus:
        total = 0.0
        for d in dus:
            ops = d.operands
            upd = fused.shapes.get(ops[1], "") if len(ops) > 1 else ""
            total += 2.0 * type_bytes(upd)
        return total
    params: Dict[str, int] = {}
    for ins in fused.instrs:
        if ins.op == "parameter":
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    total = float(type_bytes(call.rtype))          # write the result
    operands = call.operands
    for pname, idx in params.items():
        consumers = [i2 for i2 in fused.instrs
                     if pname in i2.operands]
        slice_only = consumers and all(
            c.op in ("dynamic-slice", "slice", "gather")
            and c.operands and c.operands[0] == pname
            for c in consumers)
        if slice_only:
            total += sum(type_bytes(c.rtype) for c in consumers)
        else:
            full = caller_shapes.get(operands[idx], "") \
                if idx < len(operands) else ""
            total += type_bytes(full)
    return total


def analyze_text(text: str) -> Totals:
    comps, entry = parse_hlo(text)
    memo: Dict[str, Totals] = {}

    def walk(name: str) -> Totals:
        if name in memo:
            return memo[name]
        memo[name] = Totals()          # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        t = Totals()
        for ins in comp.instrs:
            if ins.op == "while":
                body = ins.ref("body")
                cond = ins.ref("condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body in comps:
                    t.add(walk(body), trips)
                if cond in comps:
                    t.add(walk(cond), trips)
                continue
            if ins.op in ("call", "async-start"):
                tgt = ins.ref("to_apply") or ins.ref("called_computation")
                if tgt in comps:
                    t.add(walk(tgt))
                continue
            if ins.op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      ins.rest)
                names = re.findall(r"%[\w\.\-]+",
                                   branches[0]) if branches else \
                    re.findall(r"(?:true|false)_computation=(%[\w\.\-]+)",
                               ins.rest)
                sub = Totals()
                for b in names:       # upper bound: max over branches
                    cand = walk(b)
                    if cand.flops + cand.bytes > sub.flops + sub.bytes:
                        sub = cand
                t.add(sub)
                # conditional itself moves its operands/result
                t.bytes += type_bytes(ins.rtype)
                continue
            if ins.op == "fusion":
                tgt = ins.ref("calls")
                if tgt in comps:
                    # dots can live inside fusions: count their flops
                    sub = walk(tgt)
                    t.flops += sub.flops
                    for k, v in sub.coll.items():
                        t.coll[k] = t.coll.get(k, 0.0) + v
                    t.bytes += _fusion_traffic(comps[tgt], ins, comp.shapes)
                else:
                    t.bytes += type_bytes(ins.rtype) + sum(
                        type_bytes(comp.shapes.get(o, ""))
                        for o in ins.operands)
                continue
            if ins.op in _NO_TRAFFIC:
                continue
            if ins.op in _COLLECTIVES:
                b = type_bytes(ins.rtype)
                key = ins.op.replace("-start", "")
                t.coll[key] = t.coll.get(key, 0.0) + b
                t.bytes += b
                continue
            if ins.op == "dot":
                t.flops += _dot_flops(ins, comp.shapes)
            # slicing ops read only what they produce, not their operand
            if ins.op in ("dynamic-slice", "slice", "gather"):
                t.bytes += 2.0 * type_bytes(ins.rtype)
                continue
            if ins.op in ("dynamic-update-slice", "scatter"):
                upd_idx = 1 if ins.op == "dynamic-update-slice" else 2
                ops = ins.operands
                upd = comp.shapes.get(ops[upd_idx], "") \
                    if len(ops) > upd_idx else ""
                t.bytes += 2.0 * type_bytes(upd)
                continue
            # generic traffic: read operands + write result
            t.bytes += type_bytes(ins.rtype) + sum(
                type_bytes(comp.shapes.get(o, "")) for o in ins.operands)
        memo[name] = t
        return t

    return walk(entry)
