"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = per-device HLO FLOPs / peak_FLOP/s
    memory term     = per-device HLO bytes / HBM_bw
    collective term = per-device collective bytes / link_bw

(cost_analysis of the post-SPMD module is per-device, verified empirically,
so dividing by per-chip peak equals the assignment's global/(chips*peak)
for evenly-sharded programs.)

collective_bytes parses the optimized per-device HLO: for every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
we sum the op's RESULT shard bytes (all-reduce counted twice — ring
all-reduce moves ~2x the payload over the wire). Cross-pod collectives
(replica groups spanning >256-device strides) are reported separately so
the DCN story is visible.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # B/s
    link_bw: float = 50e9             # B/s per ICI link


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """-> {'all-reduce': bytes, ..., 'total': wire-bytes estimate}."""
    out: Dict[str, float] = {}
    total = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        out[op] = out.get(op, 0.0) + b
        total += b * (2.0 if op == "all-reduce" else 1.0)
    out["total"] = total
    return out


@dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    recipe: str = ""
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    out_bytes: float = 0.0
    model_flops: float = 0.0          # 6*N*D (or active) global
    n_devices: int = 0
    compile_seconds: float = 0.0

    def terms(self, hw: HW = HW()) -> Dict[str, float]:
        t_compute = self.flops_per_device / hw.peak_flops
        t_memory = self.bytes_per_device / hw.hbm_bw
        t_coll = self.coll_bytes.get("total", 0.0) / hw.link_bw
        dom = max((t_compute, "compute"), (t_memory, "memory"),
                  (t_coll, "collective"))[1]
        useful = self.model_flops / max(self.flops_per_device *
                                        self.n_devices, 1.0)
        bound = max(t_compute, t_memory, t_coll)
        # roofline fraction: useful-compute time over the achievable step
        # time bound (what fraction of the machine the model math uses)
        frac = (self.model_flops / (self.n_devices * hw.peak_flops)) \
            / bound if bound > 0 else 0.0
        return {"compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "dominant": dom,
                "useful_flops_ratio": useful, "roofline_fraction": frac}

    def to_json(self) -> dict:
        d = self.__dict__.copy()
        d["terms"] = self.terms()
        return d


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     recipe: str, model_flops: float, n_devices: int,
                     compile_seconds: float = 0.0) -> CellResult:
    from .hlo_parse import analyze_text
    ca = compiled.cost_analysis()
    # primary accounting: trip-count-aware static HLO walk (XLA's
    # cost_analysis counts while bodies once — useless under lax.scan)
    parsed = analyze_text(compiled.as_text())
    coll = dict(parsed.coll)
    coll["total"] = parsed.coll_wire_bytes
    res = CellResult(arch=arch, shape=shape, mesh=mesh_name, recipe=recipe,
                     flops_per_device=parsed.flops,
                     bytes_per_device=parsed.bytes,
                     coll_bytes=coll, model_flops=model_flops,
                     n_devices=n_devices, compile_seconds=compile_seconds)
    res.xla_cost_flops = float(ca.get("flops", 0.0))
    res.xla_cost_bytes = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        res.arg_bytes = float(ma.argument_size_in_bytes)
        res.temp_bytes = float(ma.temp_size_in_bytes)
        res.out_bytes = float(ma.output_size_in_bytes)
    except (AttributeError, NotImplementedError, RuntimeError,
            TypeError, ValueError):
        pass    # memory_analysis is best-effort: absent or unimplemented
        # on some backends/jax versions; the roofline just loses the
        # arg/temp/out byte split
    return res


def roofline_terms(result: CellResult, hw: HW = HW()) -> Dict[str, float]:
    return result.terms(hw)
