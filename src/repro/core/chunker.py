"""Tensor <-> content-addressed chunk serialization.

A pytree leaf is serialized to raw little-endian bytes and split into
fixed-size chunks. Chunks are the smallest addressable unit of the store —
the analogue of files inside a Docker ``layer.tar``. The chunk boundary is
what makes the paper's injection O(delta): an edit touching k chunks costs
k chunk writes + k hashes, independent of layer size.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Iterator, List, Tuple

import numpy as np

DEFAULT_CHUNK_BYTES = 1 << 20  # 1 MiB


def sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


@dataclass(frozen=True)
class TensorRecord:
    """Descriptor of one serialized tensor inside a layer."""

    name: str                 # pytree path, e.g. "params/blocks/attn/wq"
    shape: Tuple[int, ...]
    dtype: str                # numpy dtype string, e.g. "bfloat16"
    chunk_bytes: int
    chunks: Tuple[str, ...]   # sha256 hex of each chunk, in order

    @property
    def nbytes(self) -> int:
        n = int(np.prod(self.shape)) if self.shape else 1
        return n * dtype_itemsize(self.dtype)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "chunk_bytes": self.chunk_bytes,
            "chunks": list(self.chunks),
        }

    @staticmethod
    def from_json(d: dict) -> "TensorRecord":
        return TensorRecord(
            name=d["name"],
            shape=tuple(d["shape"]),
            dtype=d["dtype"],
            chunk_bytes=int(d["chunk_bytes"]),
            chunks=tuple(d["chunks"]),
        )


_DTYPE_SIZES = {
    "bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
    "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
    "int32": 4, "uint32": 4, "int64": 8, "uint64": 8, "bool": 1,
}


def dtype_itemsize(dtype: str) -> int:
    if dtype in _DTYPE_SIZES:
        return _DTYPE_SIZES[dtype]
    return np.dtype(dtype).itemsize


def tensor_to_bytes(arr) -> bytes:
    """Serialize an array (numpy or jax) to contiguous little-endian bytes.

    bfloat16 is handled by bit-level uint16 view (numpy has no bf16).
    """
    a = np.asarray(arr)
    if a.dtype == np.dtype("V2") or str(arr.dtype) == "bfloat16":
        # jax bf16 -> numpy via ml_dtypes view; np.asarray on a bf16 jax
        # array yields a bfloat16 ml_dtypes array; view as uint16 bits.
        a = np.asarray(arr)
        a = a.view(np.uint16)
    return np.ascontiguousarray(a).tobytes()


def bytes_to_tensor(data: bytes, shape: Tuple[int, ...], dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes  # ships with jax

        a = np.frombuffer(data, dtype=np.uint16).view(ml_dtypes.bfloat16)
    else:
        a = np.frombuffer(data, dtype=np.dtype(dtype))
    return a.reshape(shape)


def iter_chunks(data: bytes, chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> Iterator[bytes]:
    for off in range(0, max(len(data), 1), chunk_bytes):
        yield data[off:off + chunk_bytes]


def chunk_tensor(name: str, arr, chunk_bytes: int = DEFAULT_CHUNK_BYTES):
    """-> (TensorRecord, [(sha256, bytes), ...]) for every chunk."""
    dtype = str(arr.dtype)
    data = tensor_to_bytes(arr)
    pairs: List[Tuple[str, bytes]] = []
    hashes: List[str] = []
    for piece in iter_chunks(data, chunk_bytes):
        h = sha256_hex(piece)
        hashes.append(h)
        pairs.append((h, piece))
    rec = TensorRecord(
        name=name,
        shape=tuple(int(s) for s in np.shape(arr)),
        dtype=dtype,
        chunk_bytes=chunk_bytes,
        chunks=tuple(hashes),
    )
    return rec, pairs


def assemble_tensor(rec: TensorRecord, read_blob) -> np.ndarray:
    """Rebuild a tensor from its chunk records. ``read_blob(hash)->bytes``."""
    data = b"".join(read_blob(h) for h in rec.chunks)
    return bytes_to_tensor(data, rec.shape, rec.dtype)
