"""Tensor <-> content-addressed chunk serialization.

A pytree leaf is serialized to raw little-endian bytes and split into
fixed-size chunks. Chunks are the smallest addressable unit of the store —
the analogue of files inside a Docker ``layer.tar``. The chunk boundary is
what makes the paper's injection O(delta): an edit touching k chunks costs
k chunk writes + k hashes, independent of layer size.

Hot-path mechanics (the fused save pipeline, see also core/diff.py):

* ``iter_chunks`` yields zero-copy ``memoryview`` slices — splitting a
  serialized tensor allocates nothing; bytes are only copied when a chunk
  is actually written or recorded as an edit.
* ``hash_chunks`` SHA-256's chunk batches on a shared ``ThreadPoolExecutor``
  — CPython's hashlib releases the GIL for buffers >= 2 KiB, so hashing a
  multi-chunk tensor scales across cores.
* ``tensor_chunk_bytes`` serializes ONE chunk's byte range of a tensor
  without materializing the whole array — what lets the fingerprint
  prefilter touch O(changed bytes) instead of O(tensor bytes).
"""
from __future__ import annotations

import hashlib
import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_CHUNK_BYTES = 1 << 20  # 1 MiB

# Shared hashing pool. hashlib releases the GIL on large buffers, so SHA-256
# over many chunks parallelizes well; small batches stay on the caller
# thread to avoid pool dispatch overhead.
_HASH_POOL_WORKERS = min(8, os.cpu_count() or 1)
_HASH_POOL = ThreadPoolExecutor(max_workers=_HASH_POOL_WORKERS,
                                thread_name_prefix="repro-sha")
_PARALLEL_MIN_BYTES = 1 << 18   # don't fan out tiny batches


def sha256_hex(data) -> str:
    return hashlib.sha256(data).hexdigest()


def hash_pool() -> Optional[ThreadPoolExecutor]:
    """The shared SHA/transfer executor (None on single-core boxes).

    Shared by chunk hashing and the registry's pipelined blob transfer —
    tasks submitted here must hash inline (``sha256_hex``), never via
    ``hash_chunks``, so the pool cannot deadlock on itself."""
    return _HASH_POOL if _HASH_POOL_WORKERS > 1 else None


def hash_chunks(pieces: Sequence) -> List[str]:
    """SHA-256 a batch of bytes-like chunks, fanning out to the shared pool
    when the batch is large enough for the GIL release to pay off."""
    pieces = list(pieces)
    if len(pieces) > 1 and _HASH_POOL_WORKERS > 1 and \
            sum(len(p) for p in pieces) >= _PARALLEL_MIN_BYTES:
        return list(_HASH_POOL.map(sha256_hex, pieces))
    return [sha256_hex(p) for p in pieces]


@dataclass(frozen=True)
class TensorRecord:
    """Descriptor of one serialized tensor inside a layer."""

    name: str                 # pytree path, e.g. "params/blocks/attn/wq"
    shape: Tuple[int, ...]
    dtype: str                # numpy dtype string, e.g. "bfloat16"
    chunk_bytes: int
    chunks: Tuple[str, ...]   # sha256 hex of each chunk, in order
    # Optional per-chunk 64-bit fingerprint sidecar ((xor, sum) int32 pairs,
    # see core/fingerprint.py). NOT part of the layer content checksum —
    # purely a cache accelerator: lets build_image's COPY cache check
    # prefilter instead of re-chunking + re-SHA-ing the whole payload.
    fp: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def nbytes(self) -> int:
        n = int(np.prod(self.shape)) if self.shape else 1
        return n * dtype_itemsize(self.dtype)

    def to_json(self) -> dict:
        d = {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "chunk_bytes": self.chunk_bytes,
            "chunks": list(self.chunks),
        }
        if self.fp is not None:
            d["fp"] = [list(p) for p in self.fp]
        return d

    @staticmethod
    def from_json(d: dict) -> "TensorRecord":
        fp = d.get("fp")
        return TensorRecord(
            name=d["name"],
            shape=tuple(d["shape"]),
            dtype=d["dtype"],
            chunk_bytes=int(d["chunk_bytes"]),
            chunks=tuple(d["chunks"]),
            fp=tuple(tuple(int(x) for x in p) for p in fp)
            if fp is not None else None,
        )


_DTYPE_SIZES = {
    "bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
    "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
    "int32": 4, "uint32": 4, "int64": 8, "uint64": 8, "bool": 1,
}


def dtype_itemsize(dtype: str) -> int:
    if dtype in _DTYPE_SIZES:
        return _DTYPE_SIZES[dtype]
    return np.dtype(dtype).itemsize


def tensor_to_bytes(arr) -> bytes:
    """Serialize an array (numpy or jax) to contiguous little-endian bytes.

    bfloat16 is handled by bit-level uint16 view (numpy has no bf16).
    """
    a = np.asarray(arr)
    if a.dtype == np.dtype("V2") or str(arr.dtype) == "bfloat16":
        # jax bf16 -> numpy via ml_dtypes view; np.asarray on a bf16 jax
        # array yields a bfloat16 ml_dtypes array; view as uint16 bits.
        a = np.asarray(arr)
        a = a.view(np.uint16)
    return np.ascontiguousarray(a).tobytes()


def bytes_to_tensor(data: bytes, shape: Tuple[int, ...], dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes  # ships with jax

        a = np.frombuffer(data, dtype=np.uint16).view(ml_dtypes.bfloat16)
    else:
        a = np.frombuffer(data, dtype=np.dtype(dtype))
    return a.reshape(shape)


def iter_chunks(data, chunk_bytes: int = DEFAULT_CHUNK_BYTES
                ) -> Iterator[memoryview]:
    """Split a bytes-like object into chunk-sized ZERO-COPY memoryviews.

    Byte-identical to slicing ``data`` directly (``bytes(piece)`` recovers
    the old behavior); the underlying buffer must outlive the views.
    """
    mv = memoryview(data)
    for off in range(0, max(len(mv), 1), chunk_bytes):
        yield mv[off:off + chunk_bytes]


def tensor_chunk_bytes(arr, chunk_idx: int,
                       chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> bytes:
    """Serialize ONLY chunk ``chunk_idx`` of a tensor — byte-identical to
    ``tensor_to_bytes(arr)[chunk_idx*cb:(chunk_idx+1)*cb]`` but copies just
    that range (itemsize always divides the power-of-two chunk size)."""
    a = np.asarray(arr)
    if a.dtype == np.dtype("V2") or str(arr.dtype) == "bfloat16":
        a = np.asarray(arr).view(np.uint16)
    itemsize = a.dtype.itemsize
    if chunk_bytes % itemsize:
        # pathological chunk size: fall back to the full serialization
        data = tensor_to_bytes(arr)
        return bytes(data[chunk_idx * chunk_bytes:(chunk_idx + 1) * chunk_bytes])
    flat = a.ravel()            # view for contiguous arrays (the norm)
    epc = chunk_bytes // itemsize
    seg = flat[chunk_idx * epc:(chunk_idx + 1) * epc]
    return np.ascontiguousarray(seg).tobytes()


def chunk_tensor(name: str, arr, chunk_bytes: int = DEFAULT_CHUNK_BYTES):
    """-> (TensorRecord, [(sha256, memoryview), ...]) for every chunk.

    Chunk payloads are zero-copy views of one serialization buffer; hashing
    fans out to the shared pool for multi-chunk tensors.
    """
    dtype = str(arr.dtype)
    data = tensor_to_bytes(arr)
    pieces = list(iter_chunks(data, chunk_bytes))
    hashes = hash_chunks(pieces)
    pairs: List[Tuple[str, memoryview]] = list(zip(hashes, pieces))
    rec = TensorRecord(
        name=name,
        shape=tuple(int(s) for s in np.shape(arr)),
        dtype=dtype,
        chunk_bytes=chunk_bytes,
        chunks=tuple(hashes),
    )
    return rec, pairs


def assemble_tensor(rec: TensorRecord, read_blob) -> np.ndarray:
    """Rebuild a tensor from its chunk records. ``read_blob(hash)->bytes``."""
    data = b"".join(read_blob(h) for h in rec.chunks)
    return bytes_to_tensor(data, rec.shape, rec.dtype)
