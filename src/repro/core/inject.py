"""C2 + C3 + C4 — the code injection method itself.

``inject_image`` performs the paper's full pipeline on a stored image:

  1. (C1) caller supplies per-layer ``LayerDiff``s (from core.diff).
  2. (C4) clone-before-inject: each changed layer gets a NEW layer id whose
     records initially share every chunk blob with the original (an
     O(#chunks) metadata copy — blobs are content-addressed and immutable,
     so "two identical layers" costs no payload bytes). The old image and
     any other image dedup-sharing the old layer are untouched.
  3. (C2) injection: write only the changed chunk blobs into the clone.
  4. (C3) checksum bypass, "update both the key and the lock": recompute the
     clone's content checksum from its (mostly reused) chunk hashes, then
     rewrite every occurrence of the old layer id/checksum in the manifest
     and config, and re-key the chain checksums of every downstream layer.
     Downstream layers keep their content (and content checksum) — they are
     *re-keyed*, not re-built. That metadata walk is what turns the O(layer
     bytes) rebuild into O(delta + #layers) — the paper's O(n) -> O(1).
  5. Scenario-4 rule: any downstream RUN layer whose ``derives_from`` names
     an injected payload is a *derived* artifact and MUST be re-derived
     (the paper: "we must not only inject code in the layer containing the
     source code but also rebuild the layer after it that compiles the
     source code"). Its provider is re-executed; everything else is re-keyed
     only. Config layers are left to the normal (cheap, empty-layer) path.

Returns the new manifest/config plus a BuildReport whose counters benchmarks
compare against the baseline ``LayerStore.build_image`` fall-through.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .chunker import TensorRecord, chunk_tensor
from .diff import LayerDiff, diff_layer_host
from .manifest import (ImageConfig, Instruction, LayerDescriptor, Manifest,
                       chain_checksum, content_checksum, new_uuid)
from .store import BuildReport, LayerStore


class StructureChangeError(ValueError):
    """Raised when asked to inject a 'compiled' (structure) change — the
    paper's integrity rule: literal injection cannot guarantee integrity for
    compiled artifacts; callers must fall back to a rebuild."""


def clone_layer(layer: LayerDescriptor) -> LayerDescriptor:
    """C4: identical layer under a fresh id (metadata-only; blobs shared)."""
    return LayerDescriptor(
        layer_id=new_uuid(),
        version=layer.version + 1,
        instruction=layer.instruction,
        checksum=layer.checksum,
        chain=layer.chain,
        records=list(layer.records),
        empty=layer.empty,
        family=layer.family,
    )


def apply_edits(store: LayerStore, layer: LayerDescriptor, diff: LayerDiff,
                report: BuildReport) -> LayerDescriptor:
    """C2+C3 on a single (already cloned) layer."""
    if not diff.injectable:
        raise StructureChangeError(
            f"layer {diff.layer_id}: structure change is not injectable")
    by_name = {r.name: i for i, r in enumerate(layer.records)}
    records = list(layer.records)
    for edit in diff.edits:
        idx = by_name[edit.tensor]
        rec = records[idx]
        chunks = list(rec.chunks)
        chunks[edit.index] = edit.new_hash
        if store.write_blob(edit.new_hash, edit.data):
            report.chunks_written += 1
        report.bytes_serialized += len(edit.data)
        report.bytes_hashed += len(edit.data)
        records[idx] = TensorRecord(rec.name, rec.shape, rec.dtype,
                                    rec.chunk_bytes, tuple(chunks))
    layer.records = records
    layer.checksum = content_checksum(records)   # O(#chunks) metadata hash
    report.layers_injected += 1
    return layer


def inject_image(store: LayerStore,
                 name: str, tag: str, new_tag: str,
                 diffs: Dict[str, LayerDiff],
                 providers: Optional[Dict[str, Callable[[], Dict[str, np.ndarray]]]] = None,
                 ) -> Tuple[Manifest, ImageConfig, BuildReport]:
    """Run the full injection pipeline; ``diffs`` keyed by layer_id."""
    report = BuildReport()
    t0 = time.perf_counter()
    fsyncs0 = store.fsyncs
    manifest, config = store.read_image(name, tag)
    layers = [store.read_layer(lid) for lid in manifest.layer_ids]

    injected_payload_keys: set = set()
    new_layers: List[LayerDescriptor] = []
    parent_chain: Optional[str] = None
    dirty = False   # once any upstream id changed, downstream chains re-key

    for layer in layers:
        diff = diffs.get(layer.layer_id)
        ins = layer.instruction

        needs_rederive = (
            ins.op == "RUN" and not layer.empty and
            any(dep in injected_payload_keys for dep in ins.derives_from))

        if diff is not None and not diff.is_empty:
            if not diff.injectable:
                raise StructureChangeError(
                    f"layer {layer.layer_id} ({ins.text}): structure change")
            clone = clone_layer(layer)                     # C4
            clone = apply_edits(store, clone, diff, report)  # C2
            clone.chain = chain_checksum(parent_chain, clone.checksum,
                                         ins.text)          # C3 (key)
            store.write_layer(clone)
            new_layers.append(clone)
            injected_payload_keys.add(ins.arg)
            dirty = True
        elif needs_rederive:
            # Scenario-4: derived layer must actually re-run its derivation.
            if providers is None or ins.arg not in providers:
                raise StructureChangeError(
                    f"layer {layer.layer_id} derives from injected payload "
                    f"but no provider given to re-derive it")
            payload = providers[ins.arg]()
            report.derivations_run += 1
            rebuilt = store.build_content_layer(
                ins, payload, parent_chain, report,
                family=layer.family, version=layer.version + 1)
            new_layers.append(rebuilt)
            dirty = True
        elif dirty:
            # Downstream of a change: RE-KEY only (chain checksum), never
            # re-serialize. This replaces Docker's fall-through rebuild.
            clone = clone_layer(layer)
            clone.chain = chain_checksum(parent_chain, clone.checksum,
                                         ins.text)
            store.write_layer(clone)
            new_layers.append(clone)
            report.layers_rekeyed += 1
        else:
            new_layers.append(layer)
            report.layers_cached += 1

        parent_chain = new_layers[-1].chain

    new_config = ImageConfig(
        config_id=new_uuid(), arch=config.arch, version=config.version + 1,
        layer_checksums={l.layer_id: l.checksum for l in new_layers},
        layer_chains={l.layer_id: l.chain for l in new_layers},
        history=config.history + [{
            "instruction": "INJECT",
            "edits": int(sum(len(d.edits) for d in diffs.values())),
        }],
    )
    new_manifest = Manifest(name=name, tag=new_tag,
                            layer_ids=[l.layer_id for l in new_layers],
                            config_id=new_config.config_id)
    store.write_image(new_manifest, new_config)
    report.fsyncs = store.fsyncs - fsyncs0
    report.chunks_prefiltered = sum(d.chunks_prefiltered
                                    for d in diffs.values())
    report.wall_seconds = time.perf_counter() - t0
    return new_manifest, new_config, report


def inject_payload_update(store: LayerStore, name: str, tag: str,
                          new_tag: str,
                          payloads: Dict[str, Dict[str, np.ndarray]],
                          providers=None,
                          ) -> Tuple[Manifest, ImageConfig, BuildReport]:
    """Convenience: C1 (host diff) + full injection for new payload values.

    ``payloads`` maps instruction arg (payload key) -> new payload dict.
    """
    manifest, _ = store.read_image(name, tag)
    diffs: Dict[str, LayerDiff] = {}
    for lid in manifest.layer_ids:
        layer = store.read_layer(lid)
        if layer.empty:
            continue
        key = layer.instruction.arg
        if key in payloads:
            d = diff_layer_host(layer, payloads[key])
            if not d.is_empty:
                diffs[lid] = d
    return inject_image(store, name, tag, new_tag, diffs, providers)
