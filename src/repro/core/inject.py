"""C2 + C3 + C4 — the code injection method itself.

``inject_image_multi`` performs the paper's full pipeline on a stored image
for ANY number of targeted content layers in one transaction:

  1. (C1) caller supplies per-layer ``LayerDiff``s (from core.diff) keyed by
     layer_id — ``diff_image`` builds that map for a whole payload set.
  2. Validation happens for the WHOLE batch before a single byte is
     written: an unknown target, a config-layer target or a structure
     ("compiled") change aborts with the store untouched.
  3. (C4) clone-before-inject, all targeted layers UP FRONT: each changed
     layer gets a NEW layer id whose records initially share every chunk
     blob with the original (an O(#chunks) metadata copy — blobs are
     content-addressed and immutable, so "two identical layers" costs no
     payload bytes). The old image and any other image dedup-sharing the
     old layers are untouched.
  4. (C2) injection: write only the changed chunk blobs into the clones.
     Edits carrying fingerprints (``ChunkEdit.fp``) refresh the
     ``TensorRecord.fp`` sidecar in place, so the next ``build_image`` COPY
     prefilter stays a fingerprint compare instead of a full re-hash.
  5. (C3) checksum bypass, "update both the key and the lock", as ONE
     downstream walk regardless of how many layers were injected: each
     clone's content checksum was recomputed from its (mostly reused) chunk
     hashes; the chain checksums of every downstream layer are re-keyed
     exactly once. Downstream layers keep their content (and content
     checksum) — they are *re-keyed*, not re-built. Scenario-4 rule: a
     downstream RUN layer whose ``derives_from`` names ANY injected payload
     is a *derived* artifact and is re-derived — but at most ONCE, even
     when several upstream injections hit it (the paper: "we must not only
     inject code in the layer containing the source code but also rebuild
     the layer after it that compiles the source code"). Config layers are
     left to the normal (cheap, empty-layer) path.
  6. ONE manifest/config commit. Under ``durability="batch"`` (the
     default) every blob/layer fsync of the batch is deferred to this
     commit point and flushed concurrently; the manifest rename stays the
     commit point, so a crash anywhere mid-batch leaves the previous image
     fully intact (orphaned blobs are GC fodder, never corruption).

The transactional unit is therefore the IMAGE, not the layer: a save that
touches embed+blocks+head costs one walk and one commit, not three — the
per-layer O(k·#layers) metadata cost collapses back to the paper's O(1).
``BuildReport.per_layer`` attributes chunks/bytes/re-keys/re-derivations to
each source layer; ``rekey_walks`` and ``manifest_commits`` prove the
single-walk/single-commit claim.

``inject_image`` (the seed single-image API) is a thin wrapper running the
same pipeline under the store's own durability mode.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .chunker import TensorRecord
from .diff import LayerDiff, diff_image
from .manifest import (ImageConfig, LayerDescriptor, Manifest, chain_checksum,
                       content_checksum, injection_history_entry, new_uuid)
from .store import BuildReport, LayerStore


# Injection commits keep at most this many trailing history entries in the
# ImageConfig (the full per-save audit lives in the returned BuildReport).
_HISTORY_CAP = 64
# ... and each entry's delta record lists at most this many chunk ids
# (n_chunks records the true count; see the commit-phase comment).
_DELTA_CHUNKS_CAP = 256


class StructureChangeError(ValueError):
    """Raised when asked to inject a 'compiled' (structure) change — the
    paper's integrity rule: literal injection cannot guarantee integrity for
    compiled artifacts; callers must fall back to a rebuild."""


@contextlib.contextmanager
def _durability_scope(store: LayerStore, mode: Optional[str]):
    """Temporarily override the store's durability for one transaction.
    ``None`` keeps the store's own mode. The commit point (write_image ->
    sync_for_commit) always flushes deferred writes, so restoring the
    previous mode afterwards never drops durability."""
    if mode is None or mode == store.durability:
        yield
        return
    if mode not in ("full", "batch"):
        raise ValueError(f"unknown durability mode {mode!r}")
    prev = store.durability
    store.durability = mode
    try:
        yield
    finally:
        store.durability = prev


def clone_layer(layer: LayerDescriptor) -> LayerDescriptor:
    """C4: identical layer under a fresh id (metadata-only; blobs shared)."""
    return LayerDescriptor(
        layer_id=new_uuid(),
        version=layer.version + 1,
        instruction=layer.instruction,
        checksum=layer.checksum,
        chain=layer.chain,
        records=list(layer.records),
        empty=layer.empty,
        family=layer.family,
    )


def apply_edits(store: LayerStore, layer: LayerDescriptor, diff: LayerDiff,
                report: BuildReport) -> LayerDescriptor:
    """C2+C3 on a single (already cloned) layer.

    Edits carrying a new-chunk fingerprint (``ChunkEdit.fp``) refresh the
    record's fingerprint sidecar in place; an edit without one on a
    fingerprinted record computes it host-side from the chunk bytes (only
    changed chunks pay), so injection never drops the sidecar."""
    if not diff.injectable:
        raise StructureChangeError(
            f"layer {diff.layer_id}: structure change is not injectable")
    by_name = {r.name: i for i, r in enumerate(layer.records)}
    records = list(layer.records)
    for edit in diff.edits:
        idx = by_name[edit.tensor]
        rec = records[idx]
        chunks = list(rec.chunks)
        chunks[edit.index] = edit.new_hash
        fp = rec.fp
        if fp is not None:
            new_fp = edit.fp
            if new_fp is None:
                from .fingerprint import fingerprint_chunk_bytes_ref
                new_fp = fingerprint_chunk_bytes_ref(
                    edit.data, rec.dtype, rec.chunk_bytes)
            if new_fp is None:
                # misaligned chunk size: no per-chunk recompute can match
                # the whole-tensor table — drop this record's sidecar
                fp = None
            else:
                fp = list(fp)
                fp[edit.index] = (int(new_fp[0]), int(new_fp[1]))
                fp = tuple(fp)
        if store.write_blob(edit.new_hash, edit.data):
            report.chunks_written += 1
        report.bytes_serialized += len(edit.data)
        report.bytes_hashed += len(edit.data)
        records[idx] = TensorRecord(rec.name, rec.shape, rec.dtype,
                                    rec.chunk_bytes, tuple(chunks), fp=fp)
    layer.records = records
    layer.checksum = content_checksum(records)   # O(#chunks) metadata hash
    report.layers_injected += 1
    return layer


def inject_image_multi(store: LayerStore,
                       name: str, tag: str, new_tag: str,
                       diffs: Dict[str, LayerDiff],
                       providers: Optional[Dict[str, Callable[
                           [], Dict[str, np.ndarray]]]] = None,
                       *, durability: Optional[str] = "batch",
                       ) -> Tuple[Manifest, ImageConfig, BuildReport]:
    """Batched multi-layer injection (see module docstring): validate all,
    clone+inject all targeted layers up front, then ONE downstream re-key
    walk and ONE manifest/config commit. ``diffs`` keyed by layer_id.

    ``durability``: mode for this transaction's blob/layer writes —
    "batch" (default: one concurrent fsync flush at the commit point),
    "full", or None to keep the store's own mode.
    """
    report = BuildReport()
    t0 = time.perf_counter()
    fsyncs0, commits0 = store.fsyncs, store.commits
    manifest, config = store.read_image(name, tag)
    layers = [store.read_layer(lid) for lid in manifest.layer_ids]
    by_id = {layer.layer_id: layer for layer in layers}

    # Validate the WHOLE batch before any write hits the store.
    live: Dict[str, LayerDiff] = {}
    for lid, diff in diffs.items():
        if diff.is_empty:
            continue
        layer = by_id.get(lid)
        if layer is None:
            raise KeyError(f"layer {lid} is not part of {name}:{tag}")
        if layer.empty:
            raise StructureChangeError(
                f"layer {lid} ({layer.instruction.text}): config layers "
                "take the normal empty-layer rebuild path, not injection")
        if not diff.injectable:
            raise StructureChangeError(
                f"layer {lid} ({layer.instruction.text}): structure change")
        live[lid] = diff

    # Still pre-write: resolve the walk's Scenario-4 derivation cascade
    # ONCE (derives_from is static metadata), so a missing provider aborts
    # before any blob exists and Phase B just consumes the plan.
    will_change: set = set()
    rederive_ids: set = set()
    for layer in layers:
        ins = layer.instruction
        if layer.layer_id in live:
            will_change.add(ins.arg)
        elif ins.op == "RUN" and not layer.empty and \
                any(dep in will_change for dep in ins.derives_from):
            if providers is None or ins.arg not in providers:
                raise StructureChangeError(
                    f"layer {layer.layer_id} derives from injected payload "
                    f"but no provider given to re-derive it")
            rederive_ids.add(layer.layer_id)
            will_change.add(ins.arg)

    with _durability_scope(store, durability):
        # Phase A — C4+C2: clone every targeted layer up front and write
        # only the changed chunk blobs into the clones.
        clones: Dict[str, LayerDescriptor] = {}
        for lid, diff in live.items():
            entry = report.layer_entry(lid)
            chunks0, bytes0 = report.chunks_written, report.bytes_serialized
            clones[lid] = apply_edits(store, clone_layer(by_id[lid]), diff,
                                      report)
            entry["chunks_written"] += report.chunks_written - chunks0
            entry["bytes_written"] += report.bytes_serialized - bytes0

        # Phase B — C3: the single downstream re-key walk, consuming the
        # pre-resolved derivation plan (rederive_ids). ``delta`` records
        # this commit's replication unit (core.delta): old->new layer maps
        # by change kind plus the chunk ids written — what a delta push of
        # this commit has to carry.
        report.rekey_walks += 1
        delta = {"base": [name, tag], "injected": {}, "rederived": {},
                 "rekeyed": {}}
        delta_chunks = {e.new_hash for d in live.values() for e in d.edits}
        new_layers: List[LayerDescriptor] = []
        parent_chain: Optional[str] = None
        dirty = False   # once any upstream id changed, downstream re-keys
        for layer in layers:
            ins = layer.instruction
            clone = clones.get(layer.layer_id)
            if clone is not None:
                clone.chain = chain_checksum(parent_chain, clone.checksum,
                                             ins.text)
                store.write_layer(clone)
                new_layers.append(clone)
                delta["injected"][clone.layer_id] = layer.layer_id
                dirty = True
            elif layer.layer_id in rederive_ids:
                # Scenario-4: a derived layer re-runs its derivation — once
                # per batch, no matter how many upstream injections hit it.
                entry = report.layer_entry(layer.layer_id)
                chunks0 = report.chunks_written
                bytes0 = report.bytes_serialized
                payload = providers[ins.arg]()
                report.derivations_run += 1
                rebuilt = store.build_content_layer(
                    ins, payload, parent_chain, report,
                    family=layer.family, version=layer.version + 1)
                entry["rederived"] += 1
                entry["chunks_written"] += report.chunks_written - chunks0
                entry["bytes_written"] += report.bytes_serialized - bytes0
                new_layers.append(rebuilt)
                delta["rederived"][rebuilt.layer_id] = layer.layer_id
                delta_chunks.update(h for rec in rebuilt.records
                                    for h in rec.chunks)
                dirty = True
            elif dirty:
                # Downstream of a change: RE-KEY only (chain checksum),
                # never re-serialize — Docker's fall-through replaced.
                rekeyed = clone_layer(layer)
                rekeyed.chain = chain_checksum(parent_chain,
                                               rekeyed.checksum, ins.text)
                store.write_layer(rekeyed)
                new_layers.append(rekeyed)
                delta["rekeyed"][rekeyed.layer_id] = layer.layer_id
                report.layers_rekeyed += 1
                report.layer_entry(layer.layer_id)["rekeyed"] += 1
            else:
                new_layers.append(layer)
                report.layers_cached += 1
            parent_chain = new_layers[-1].chain

        # Phase C — ONE manifest/config commit (the crash-safety point).
        # History is capped: the config is copied forward and re-fsynced on
        # every commit, so an unbounded audit trail would quietly turn the
        # O(delta) save into O(total saves) of config serialization.
        # The chunk-id list in the history record is CAPPED: the config is
        # copied forward and re-fsync'd on every commit, so a save touching
        # thousands of chunks must not turn the audit trail into megabytes
        # of hashes x 64 retained entries. n_chunks always has the truth;
        # replication never reads this list (push_delta negotiates a live
        # have-set, export_delta re-diffs via diff_manifests).
        delta["n_chunks"] = len(delta_chunks)
        delta["chunks"] = sorted(delta_chunks)[:_DELTA_CHUNKS_CAP]
        total_edits = sum(len(d.edits) for d in live.values())
        history = (config.history +
                   [injection_history_entry(report.per_layer, total_edits,
                                            delta=delta)])[-_HISTORY_CAP:]
        new_config = ImageConfig(
            config_id=new_uuid(), arch=config.arch,
            version=config.version + 1,
            layer_checksums={l.layer_id: l.checksum for l in new_layers},
            layer_chains={l.layer_id: l.chain for l in new_layers},
            history=history,
        )
        new_manifest = Manifest(name=name, tag=new_tag,
                                layer_ids=[l.layer_id for l in new_layers],
                                config_id=new_config.config_id)
        store.write_image(new_manifest, new_config)

    report.fsyncs = store.fsyncs - fsyncs0
    report.manifest_commits = store.commits - commits0
    report.chunks_prefiltered = sum(d.chunks_prefiltered
                                    for d in diffs.values())
    report.wall_seconds = time.perf_counter() - t0
    return new_manifest, new_config, report


def inject_image(store: LayerStore,
                 name: str, tag: str, new_tag: str,
                 diffs: Dict[str, LayerDiff],
                 providers: Optional[Dict[str, Callable[
                     [], Dict[str, np.ndarray]]]] = None,
                 ) -> Tuple[Manifest, ImageConfig, BuildReport]:
    """Seed-compatible single-transaction API: the same pipeline under the
    store's own durability mode (batch by default store-wide; a store
    opened with durability="full" keeps its per-write fsync accounting)."""
    return inject_image_multi(store, name, tag, new_tag, diffs, providers,
                              durability=None)


def inject_payload_update(store: LayerStore, name: str, tag: str,
                          new_tag: str,
                          payloads: Dict[str, Dict[str, np.ndarray]],
                          providers=None,
                          ) -> Tuple[Manifest, ImageConfig, BuildReport]:
    """Convenience: C1 (host diff) + full injection for new payload values.

    ``payloads`` maps instruction arg (payload key) -> new payload dict.
    """
    manifest, _ = store.read_image(name, tag)
    layers = [store.read_layer(lid) for lid in manifest.layer_ids]
    diffs = diff_image(layers, payloads)
    return inject_image(store, name, tag, new_tag, diffs, providers)
