"""DeltaBundle — the O(changed-bytes) redeployment wire format.

Every batched injection commit (``inject_image_multi``) changes an image by
a small, precisely-known delta: the injected chunk blobs, the cloned-layer
descriptors, the downstream re-key table and a fresh manifest/config. A
``DeltaBundle`` packages exactly that — nothing else crosses the wire on a
push — which is what turns §III.C redeployment from O(image) into
O(changed bytes) (cf. Charliecloud's pack-style build-cache transfer,
arXiv:2309.00166).

Wire layout (``encode_delta``/``decode_delta``)::

    b"RDB1" | uint64 header_len | header JSON | blob payloads (index order)

The header carries the manifest, config, layer descriptors, the re-key
table ({new_layer_id: remote_layer_id} for content-identical clones), the
cross-image base hints (``base_images`` — sibling images the delta was
computed against, e.g. the base model a fine-tune forked from) and a
blob index [[sha256, length], ...]; payloads follow concatenated in index
order. Decoding verifies each payload against its content address, so a
bundle is self-checking — the receiving side never has to trust lengths or
offsets.

``core.diff.diff_manifests`` computes the delta between two *stored* images
at the metadata level (family + content-checksum matching): the basis for
offline bundles (``registry.export_delta``) when no live remote is
available to negotiate a have-set with.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import struct
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .chunker import sha256_hex
from .manifest import ImageConfig, LayerDescriptor, Manifest, dumps

_MAGIC = b"RDB1"


class DeltaFormatError(ValueError):
    """Raised when a bundle fails structural or content-address checks."""


@dataclass
class DeltaBundle:
    """One image transition, self-contained: apply on top of whatever the
    receiver already holds (``rekey`` names the holdings it may reuse)."""

    name: str
    tag: str                            # the tag this bundle produces
    base_tag: str = ""                  # provenance only ("" = unknown/full)
    manifest: Manifest = None
    config: ImageConfig = None
    layers: List[LayerDescriptor] = field(default_factory=list)
    # new_layer_id -> layer_id the receiver already holds with the SAME
    # content checksum (a re-keyed clone): receiving side can skip deep
    # verification for these — content identical, only the chain moved.
    rekey: Dict[str, str] = field(default_factory=dict)
    blobs: Dict[str, bytes] = field(default_factory=dict)
    # Cross-image base hints: sibling image names the delta was ALSO
    # computed against (registry.export_delta's ``base_images``). Layers
    # and chunks reachable from those images' committed tags are omitted
    # from the bundle — a fine-tune's bundle carries only adapter deltas
    # when the receiver holds the base under another name. Purely
    # advisory provenance for the receiver: its own cross-image holdings
    # index answers the have-set either way, so an old decoder (or an
    # empty list) only costs bundle size, never correctness.
    base_images: List[str] = field(default_factory=list)

    @property
    def payload_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())

    def layer_meta(self, held=None) -> Dict[str, tuple]:
        """{layer_id: (family, content_checksum)} for EVERY manifest layer,
        in manifest order — the negotiation request a live push derives
        from its source store, reconstructed from the bundle header so an
        offline relay (``registry.import_delta`` at a ``RelayNode``) can
        seed its children with the same have-set exchange. Families of
        layers the bundle doesn't carry come from ``held`` (a lookup
        returning the receiver's own descriptor, or None); a layer known
        to neither side keeps the config's checksum lock with an empty
        family, which only costs a missed re-key match downstream, never
        correctness."""
        carried = {layer.layer_id: layer for layer in self.layers}
        meta: Dict[str, tuple] = {}
        for lid in self.manifest.layer_ids:
            layer = carried.get(lid)
            if layer is None and held is not None:
                layer = held(lid)
            meta[lid] = (layer.family, layer.checksum) if layer is not None \
                else ("", self.config.layer_checksums.get(lid, ""))
        return meta


def encode_delta(bundle: DeltaBundle) -> bytes:
    index = sorted(bundle.blobs.keys())
    header = {
        "name": bundle.name,
        "tag": bundle.tag,
        "base_tag": bundle.base_tag,
        "base_images": list(bundle.base_images),
        "manifest": bundle.manifest.to_json(),
        "config": bundle.config.to_json(),
        "layers": [layer.to_json() for layer in bundle.layers],
        "rekey": dict(bundle.rekey),
        "blob_index": [[h, len(bundle.blobs[h])] for h in index],
    }
    head = dumps(header).encode()
    parts = [_MAGIC, struct.pack("<Q", len(head)), head]
    parts.extend(bundle.blobs[h] for h in index)
    return b"".join(parts)


def decode_delta(data: bytes) -> DeltaBundle:
    if len(data) < 12 or data[:4] != _MAGIC:
        raise DeltaFormatError("not a delta bundle (bad magic / truncated)")
    (head_len,) = struct.unpack("<Q", data[4:12])
    if 12 + head_len > len(data):
        raise DeltaFormatError("truncated bundle header")
    header = json.loads(data[12:12 + head_len])
    blobs: Dict[str, bytes] = {}
    off = 12 + head_len
    for h, length in header["blob_index"]:
        piece = data[off:off + length]
        if len(piece) != length:
            raise DeltaFormatError(f"truncated payload for blob {h[:12]}")
        if sha256_hex(piece) != h:
            raise DeltaFormatError(f"payload does not match address {h[:12]}")
        blobs[h] = piece
        off += length
    if off != len(data):
        raise DeltaFormatError("trailing bytes after last payload")
    manifest = Manifest.from_json(header["manifest"])
    return DeltaBundle(
        name=header["name"],
        tag=header["tag"],
        base_tag=header.get("base_tag", ""),
        base_images=list(header.get("base_images", [])),
        manifest=manifest,
        config=ImageConfig.from_json(header["config"]),
        layers=[LayerDescriptor.from_json(d) for d in header["layers"]],
        rekey=dict(header.get("rekey", {})),
        blobs=blobs,
    )


# --------------------------------------------------------------- squashing

def compose_delta_records(records: Sequence[dict]) -> Dict[str, Tuple[str, bool]]:
    """Chain a contiguous run of per-commit delta records end-to-end.

    Each record (``injection_history_entry``'s ``delta``) maps
    ``{new_layer_id: old_layer_id}`` three ways — ``injected`` and
    ``rederived`` (content changed) and ``rekeyed`` (content identical,
    only the chain checksum moved). Composing the run means following
    each layer's identity through every hop: a layer injected at hop 2
    and re-keyed at hops 3..k is ONE content change against the base,
    and a layer only ever re-keyed is none at all.

    Returns ``{final_layer_id: (base_layer_id, content_changed)}`` for
    every layer id touched anywhere in the run, keyed by the id it ends
    the run with. Layers absent from the map were never touched (their
    id is shared with the base verbatim). Intermediate hops' chunk lists
    are deliberately NOT composed here — ``squash_deltas`` derives the
    final chunk set from the store so same-chunk overwrites collapse to
    the final bytes by construction (the capped per-record chunk lists
    are advisory)."""
    origin: Dict[str, Tuple[str, bool]] = {}
    for record in records:
        step: Dict[str, Tuple[str, bool]] = {}
        for kind, changes in (("injected", True), ("rederived", True),
                              ("rekeyed", False)):
            for new, old in (record.get(kind) or {}).items():
                base, changed = origin.pop(old, (old, False))
                step[new] = (base, changed or changes)
        origin.update(step)
    return origin


# ------------------------------------------------------------ bundle index

INDEX_VERSION = 1


@dataclass
class BundleEntry:
    """One published static bundle: apply on ``from_tag`` to reach
    ``to_tag``. ``from_tag == ""`` is a FULL bundle — applicable from
    nothing (and therefore from any state). ``path`` is relative to the
    image's directory in the passive registry; ``size``/``sha256`` are
    the advertised wire cost and the content address a fetcher must
    verify before decoding."""

    from_tag: str
    to_tag: str
    path: str
    size: int
    sha256: str

    def to_json(self) -> dict:
        return {"from": self.from_tag, "to": self.to_tag,
                "path": self.path, "size": int(self.size),
                "sha256": self.sha256}

    @staticmethod
    def from_json(d: dict) -> "BundleEntry":
        return BundleEntry(from_tag=str(d["from"]), to_tag=str(d["to"]),
                           path=str(d["path"]), size=int(d["size"]),
                           sha256=str(d["sha256"]))


@dataclass
class BundleIndex:
    """The passive registry's advertisement for one image: which (from,
    to) bundles exist, at what byte cost, plus the head tag the
    publisher most recently completed. Plain signed JSON a dumb HTTP /
    object store serves as a file — the whole point is that followers
    plan their pull from this document alone, with zero negotiation
    round-trips against anything smart."""

    image: str
    head: str
    generation: int = 0          # bumped per publish; detects staleness
    entries: List[BundleEntry] = field(default_factory=list)

    def entry(self, from_tag: str, to_tag: str) -> Optional[BundleEntry]:
        for e in self.entries:
            if e.from_tag == from_tag and e.to_tag == to_tag:
                return e
        return None


def _index_body(index: BundleIndex) -> dict:
    return {"version": INDEX_VERSION, "image": index.image,
            "head": index.head, "generation": int(index.generation),
            "entries": [e.to_json() for e in index.entries]}


def _index_sig(body: dict, key: bytes) -> str:
    return hmac.new(key, dumps(body).encode(), hashlib.sha256).hexdigest()


def encode_index(index: BundleIndex, key: bytes = b"") -> bytes:
    """Serialize + sign a bundle index. The signature is HMAC-SHA256
    over the canonical body JSON: with a shared ``key`` it proves
    authenticity, with the default empty key it is still a keyed-hash
    integrity check that catches truncation and bit-rot (a reader with
    any key rejects a tampered body either way)."""
    body = _index_body(index)
    return dumps({"body": body, "sig": _index_sig(body, key)}).encode()


def decode_index(data: bytes, key: bytes = b"") -> BundleIndex:
    """Parse + verify a signed bundle index; ``DeltaFormatError`` on any
    structural or signature failure — an unusable index, never a wrong
    plan."""
    try:
        doc = json.loads(data)
        body, sig = doc["body"], doc["sig"]
    except (ValueError, TypeError, KeyError) as exc:
        raise DeltaFormatError(f"malformed bundle index: {exc}") from exc
    if not hmac.compare_digest(_index_sig(body, key), str(sig)):
        raise DeltaFormatError("bundle index signature mismatch")
    if body.get("version") != INDEX_VERSION:
        raise DeltaFormatError(
            f"unsupported index version {body.get('version')!r}")
    try:
        return BundleIndex(
            image=str(body["image"]), head=str(body["head"]),
            generation=int(body["generation"]),
            entries=[BundleEntry.from_json(d) for d in body["entries"]])
    except (ValueError, TypeError, KeyError) as exc:
        raise DeltaFormatError(f"malformed bundle index body: {exc}") from exc


def plan_bundle_chain(index: BundleIndex, held_tags: Iterable[str],
                      head: Optional[str] = None,
                      skip: Iterable[Tuple[str, str]] = ()
                      ) -> Optional[List[BundleEntry]]:
    """Cheapest chain of published bundles carrying a store that holds
    ``held_tags`` to ``head`` (default: the index head), by ADVERTISED
    byte cost — Dijkstra over the index's (from, to) edges, where every
    held tag (and the empty tag, reaching full bundles) is a zero-cost
    source. A single squashed bundle, a k-hop chain and a full pull all
    compete on equal footing; ties break deterministically toward fewer
    hops, then entry order.

    ``skip`` removes (from, to) edges already found unusable (fetch
    failed, hash mismatch, pruned on the far side) so a caller can
    replan mid-pull without them. Tags in the index that the follower
    pruned locally simply never become sources; entries whose bundles
    vanished remotely surface as fetch failures and come back through
    ``skip`` — either way the planner skips unusable chains instead of
    raising. Returns ``[]`` when ``head`` is already held, None when no
    chain reaches it."""
    import heapq

    head = head if head is not None else index.head
    held: Set[str] = set(held_tags)
    if head in held:
        return []
    skipped = set(skip)
    edges: Dict[str, List[Tuple[int, BundleEntry]]] = {}
    for order, e in enumerate(index.entries):
        if (e.from_tag, e.to_tag) in skipped:
            continue
        edges.setdefault(e.from_tag, []).append((order, e))
    # dist: tag -> (bytes, hops); prev: tag -> (entry, source_tag)
    dist: Dict[str, Tuple[int, int]] = {}
    prev: Dict[str, Tuple[BundleEntry, str]] = {}
    heap: List[Tuple[int, int, int, str]] = []
    for order, src in enumerate(sorted(held) + [""]):
        dist[src] = (0, 0)
        heapq.heappush(heap, (0, 0, order, src))
    seq = len(dist)
    while heap:
        cost, hops, _, tag = heapq.heappop(heap)
        if (cost, hops) > dist.get(tag, (cost, hops)):
            continue            # stale heap entry
        if tag == head:
            chain: List[BundleEntry] = []
            while tag in prev:
                entry, tag = prev[tag]
                chain.append(entry)
            chain.reverse()
            return chain
        for order, e in edges.get(tag, ()):
            cand = (cost + max(int(e.size), 0), hops + 1)
            if cand < dist.get(e.to_tag, (float("inf"), 0)):
                dist[e.to_tag] = cand
                prev[e.to_tag] = (e, tag)
                seq += 1
                heapq.heappush(heap, (*cand, seq, e.to_tag))
    return None
