"""DeltaBundle — the O(changed-bytes) redeployment wire format.

Every batched injection commit (``inject_image_multi``) changes an image by
a small, precisely-known delta: the injected chunk blobs, the cloned-layer
descriptors, the downstream re-key table and a fresh manifest/config. A
``DeltaBundle`` packages exactly that — nothing else crosses the wire on a
push — which is what turns §III.C redeployment from O(image) into
O(changed bytes) (cf. Charliecloud's pack-style build-cache transfer,
arXiv:2309.00166).

Wire layout (``encode_delta``/``decode_delta``)::

    b"RDB1" | uint64 header_len | header JSON | blob payloads (index order)

The header carries the manifest, config, layer descriptors, the re-key
table ({new_layer_id: remote_layer_id} for content-identical clones), the
cross-image base hints (``base_images`` — sibling images the delta was
computed against, e.g. the base model a fine-tune forked from) and a
blob index [[sha256, length], ...]; payloads follow concatenated in index
order. Decoding verifies each payload against its content address, so a
bundle is self-checking — the receiving side never has to trust lengths or
offsets.

``core.diff.diff_manifests`` computes the delta between two *stored* images
at the metadata level (family + content-checksum matching): the basis for
offline bundles (``registry.export_delta``) when no live remote is
available to negotiate a have-set with.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field
from typing import Dict, List

from .chunker import sha256_hex
from .manifest import ImageConfig, LayerDescriptor, Manifest, dumps

_MAGIC = b"RDB1"


class DeltaFormatError(ValueError):
    """Raised when a bundle fails structural or content-address checks."""


@dataclass
class DeltaBundle:
    """One image transition, self-contained: apply on top of whatever the
    receiver already holds (``rekey`` names the holdings it may reuse)."""

    name: str
    tag: str                            # the tag this bundle produces
    base_tag: str = ""                  # provenance only ("" = unknown/full)
    manifest: Manifest = None
    config: ImageConfig = None
    layers: List[LayerDescriptor] = field(default_factory=list)
    # new_layer_id -> layer_id the receiver already holds with the SAME
    # content checksum (a re-keyed clone): receiving side can skip deep
    # verification for these — content identical, only the chain moved.
    rekey: Dict[str, str] = field(default_factory=dict)
    blobs: Dict[str, bytes] = field(default_factory=dict)
    # Cross-image base hints: sibling image names the delta was ALSO
    # computed against (registry.export_delta's ``base_images``). Layers
    # and chunks reachable from those images' committed tags are omitted
    # from the bundle — a fine-tune's bundle carries only adapter deltas
    # when the receiver holds the base under another name. Purely
    # advisory provenance for the receiver: its own cross-image holdings
    # index answers the have-set either way, so an old decoder (or an
    # empty list) only costs bundle size, never correctness.
    base_images: List[str] = field(default_factory=list)

    @property
    def payload_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())

    def layer_meta(self, held=None) -> Dict[str, tuple]:
        """{layer_id: (family, content_checksum)} for EVERY manifest layer,
        in manifest order — the negotiation request a live push derives
        from its source store, reconstructed from the bundle header so an
        offline relay (``registry.import_delta`` at a ``RelayNode``) can
        seed its children with the same have-set exchange. Families of
        layers the bundle doesn't carry come from ``held`` (a lookup
        returning the receiver's own descriptor, or None); a layer known
        to neither side keeps the config's checksum lock with an empty
        family, which only costs a missed re-key match downstream, never
        correctness."""
        carried = {layer.layer_id: layer for layer in self.layers}
        meta: Dict[str, tuple] = {}
        for lid in self.manifest.layer_ids:
            layer = carried.get(lid)
            if layer is None and held is not None:
                layer = held(lid)
            meta[lid] = (layer.family, layer.checksum) if layer is not None \
                else ("", self.config.layer_checksums.get(lid, ""))
        return meta


def encode_delta(bundle: DeltaBundle) -> bytes:
    index = sorted(bundle.blobs.keys())
    header = {
        "name": bundle.name,
        "tag": bundle.tag,
        "base_tag": bundle.base_tag,
        "base_images": list(bundle.base_images),
        "manifest": bundle.manifest.to_json(),
        "config": bundle.config.to_json(),
        "layers": [layer.to_json() for layer in bundle.layers],
        "rekey": dict(bundle.rekey),
        "blob_index": [[h, len(bundle.blobs[h])] for h in index],
    }
    head = dumps(header).encode()
    parts = [_MAGIC, struct.pack("<Q", len(head)), head]
    parts.extend(bundle.blobs[h] for h in index)
    return b"".join(parts)


def decode_delta(data: bytes) -> DeltaBundle:
    if len(data) < 12 or data[:4] != _MAGIC:
        raise DeltaFormatError("not a delta bundle (bad magic / truncated)")
    (head_len,) = struct.unpack("<Q", data[4:12])
    if 12 + head_len > len(data):
        raise DeltaFormatError("truncated bundle header")
    header = json.loads(data[12:12 + head_len])
    blobs: Dict[str, bytes] = {}
    off = 12 + head_len
    for h, length in header["blob_index"]:
        piece = data[off:off + length]
        if len(piece) != length:
            raise DeltaFormatError(f"truncated payload for blob {h[:12]}")
        if sha256_hex(piece) != h:
            raise DeltaFormatError(f"payload does not match address {h[:12]}")
        blobs[h] = piece
        off += length
    if off != len(data):
        raise DeltaFormatError("trailing bytes after last payload")
    manifest = Manifest.from_json(header["manifest"])
    return DeltaBundle(
        name=header["name"],
        tag=header["tag"],
        base_tag=header.get("base_tag", ""),
        base_images=list(header.get("base_images", [])),
        manifest=manifest,
        config=ImageConfig.from_json(header["config"]),
        layers=[LayerDescriptor.from_json(d) for d in header["layers"]],
        rekey=dict(header.get("rekey", {})),
        blobs=blobs,
    )
