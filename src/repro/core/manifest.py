"""Image / layer metadata — the Docker ``manifest.json`` + ``config.json`` split.

Faithful structure (paper Table III-A):

* ``Manifest``  — config pointer, repo tag, ordered list of layer pointers.
* ``ImageConfig`` — per-layer checksum + instruction trace + version: the
  "lock". Integrity verification recomputes each layer's content checksum
  from its chunk hashes and compares against the config — so an in-place
  content edit *without* re-keying the config fails verification, exactly
  the property the paper's "checksum bypass" (C3) must defeat by updating
  both the key and the lock.
* ``LayerDescriptor`` — id (permanent UUID), version, instruction,
  content checksum (over chunk hashes), chain checksum (hash chain with the
  parent — what makes fall-through structural), tensor records, empty flag.
"""
from __future__ import annotations

import json
import uuid
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .chunker import TensorRecord, sha256_hex


def new_uuid() -> str:
    return uuid.uuid4().hex


@dataclass
class Instruction:
    op: str                     # FROM | COPY | RUN | ENV | CMD | LABEL
    arg: str                    # payload key or literal
    kind: str                   # "content" | "config"
    derives_from: List[str] = field(default_factory=list)
    # ^ semantic dependencies (payload keys of earlier content layers this
    # derivation actually reads). Docker ignores this — it falls through on
    # *positional* order; injection honors it (the paper's scenario-4 rule:
    # a compile layer must be re-run when its source layer is injected).

    @property
    def text(self) -> str:
        return f"{self.op} {self.arg}"

    def to_json(self) -> dict:
        return {"op": self.op, "arg": self.arg, "kind": self.kind,
                "derives_from": self.derives_from}

    @staticmethod
    def from_json(d: dict) -> "Instruction":
        return Instruction(d["op"], d["arg"], d["kind"],
                           list(d.get("derives_from", [])))


def content_checksum(records: Sequence[TensorRecord]) -> str:
    """Layer content checksum = sha256 over the ordered chunk-hash list.

    O(#chunks), not O(bytes): after injection only the changed chunks were
    re-hashed; the layer checksum recompute is metadata-cheap. This is the
    "compute the checksum of the new layer" step of C3.
    """
    h = "|".join(f"{r.name}:{','.join(r.chunks)}" for r in records)
    return sha256_hex(h.encode())


def chain_checksum(parent_chain: Optional[str], own_content: str,
                   instruction_text: str) -> str:
    """Docker-style hash chain: layer identity commits to everything above it.

    This is what makes fall-through *structural*: change layer k's content
    and every later chain checksum changes, so a rebuilder that keys caches
    on chain checksums must rebuild k+1..N.
    """
    return sha256_hex(f"{parent_chain or ''}+{own_content}+{instruction_text}".encode())


def injection_history_entry(per_layer: Dict[str, Dict[str, int]],
                            total_edits: int,
                            delta: Optional[dict] = None) -> dict:
    """ImageConfig history record for ONE batched injection commit.

    ``per_layer`` mirrors ``BuildReport.per_layer`` (keyed by the source
    image's layer ids), so the image history itself attributes which layer
    cost what in the batch — the audit trail for the multi-layer
    transactional unit.

    ``delta`` is the commit's DeltaBundle metadata (see core.delta): the
    base tag, the old->new layer maps split by how each layer changed
    (injected / rederived / rekeyed — the downstream re-key table), and the
    chunk ids written by this commit. It makes every injection commit a
    self-describing replication unit: a registry can reconstruct what a
    push must carry without re-diffing the stores."""
    entry = {"instruction": "INJECT", "edits": int(total_edits),
             "per_layer": {lid: dict(entry)
                           for lid, entry in per_layer.items()}}
    if delta is not None:
        entry["delta"] = delta
    return entry


def history_delta_chain(config: "ImageConfig", name: str,
                        from_tag: str) -> Optional[List[dict]]:
    """The ordered per-commit delta records carrying ``name:from_tag`` to
    the revision this config locks — the raw material
    ``registry.squash_deltas`` composes into one static bundle.

    Every batched injection appends a self-describing ``delta`` record
    (``injection_history_entry(delta=...)``) to the base's cumulative
    history, so the lineage from ``from_tag`` is exactly the history
    suffix starting at the LAST entry whose ``delta["base"]`` names
    ``from_tag`` (later commits re-based on the same tag supersede
    earlier branches that cannot lead here). Records carry only their
    BASE tag; each suffix entry's base is the implied result of its
    predecessor, which is what makes the suffix contiguous by
    construction. Returns None when the chain cannot be recovered —
    ``from_tag`` fell off the capped history, a non-injection commit
    (full rebuild, structure change) sits in the span, or a record in
    the span has no delta — and the caller must fall back to a
    store-level re-diff (``registry.diff_manifests``)."""
    start = None
    for i, entry in enumerate(config.history):
        d = entry.get("delta") or {}
        base = list(d.get("base") or ())
        if len(base) >= 2 and base[0] == name and base[1] == from_tag:
            start = i
    if start is None:
        return None
    chain: List[dict] = []
    for entry in config.history[start:]:
        d = entry.get("delta")
        base = list((d or {}).get("base") or ())
        if not d or len(base) < 2 or base[0] != name:
            return None
        chain.append(d)
    return chain


@dataclass
class LayerDescriptor:
    layer_id: str               # unique per revision (descriptor identity —
                                # crash safety: a rebuild NEVER overwrites
                                # the previous revision's descriptor)
    version: int
    instruction: Instruction
    checksum: str               # content checksum (over chunk hashes)
    chain: str                  # chain checksum (parent-linked)
    records: List[TensorRecord] = field(default_factory=list)
    empty: bool = False         # config layers carry no content
    family: str = ""            # the paper's "permanent UUID": stable
                                # across revisions of the same layer

    def __post_init__(self):
        if not self.family:
            self.family = self.layer_id

    def to_json(self) -> dict:
        return {
            "id": self.layer_id,
            "family": self.family,
            "version": self.version,
            "instruction": self.instruction.to_json(),
            "layer-checksum": self.checksum,
            "chain-checksum": self.chain,
            "isEmptyLayer": self.empty,
            "tensors": [r.to_json() for r in self.records],
        }

    @staticmethod
    def from_json(d: dict) -> "LayerDescriptor":
        return LayerDescriptor(
            layer_id=d["id"],
            version=int(d["version"]),
            instruction=Instruction.from_json(d["instruction"]),
            checksum=d["layer-checksum"],
            chain=d["chain-checksum"],
            records=[TensorRecord.from_json(r) for r in d.get("tensors", [])],
            empty=bool(d.get("isEmptyLayer", False)),
            family=d.get("family", d["id"]),
        )

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self.records)


@dataclass
class Manifest:
    """The "key": which layers, in which order, make this image."""

    name: str
    tag: str
    layer_ids: List[str]
    config_id: str

    def to_json(self) -> dict:
        return {"RepoTags": [f"{self.name}:{self.tag}"],
                "Layers": list(self.layer_ids),
                "Config": self.config_id}

    @staticmethod
    def from_json(d: dict) -> "Manifest":
        name, tag = d["RepoTags"][0].split(":", 1)
        return Manifest(name=name, tag=tag, layer_ids=list(d["Layers"]),
                        config_id=d["Config"])


@dataclass
class ImageConfig:
    """The "lock": per-layer checksums + build history."""

    config_id: str
    arch: str
    version: int
    layer_checksums: Dict[str, str]      # layer_id -> content checksum
    layer_chains: Dict[str, str]         # layer_id -> chain checksum
    history: List[dict] = field(default_factory=list)

    def to_json(self) -> dict:
        return {
            "id": self.config_id,
            "arch": self.arch,
            "version": self.version,
            "layer-checksums": dict(self.layer_checksums),
            "chain-checksums": dict(self.layer_chains),
            "history": list(self.history),
        }

    @staticmethod
    def from_json(d: dict) -> "ImageConfig":
        return ImageConfig(
            config_id=d["id"],
            arch=d["arch"],
            version=int(d["version"]),
            layer_checksums=dict(d["layer-checksums"]),
            layer_chains=dict(d["chain-checksums"]),
            history=list(d.get("history", [])),
        )


def dumps(obj: dict) -> str:
    return json.dumps(obj, indent=1, sort_keys=True)
