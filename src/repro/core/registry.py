"""Remote-registry model — paper §III.C (redeployment).

A "remote" is another LayerStore behind a ``DeltaReceiver`` — the endpoint
of the wire protocol, which *verifies everything it receives*. Two push
paths share the same integrity gate (a naive in-place mutation — same layer
id, diverged checksum — is REJECTED; a clone-before-inject with a new id
and re-keyed manifest is ACCEPTED):

* ``push`` — the seed O(image) baseline: walk every layer, send missing
  blobs one at a time, then ``verify_image(deep=True)`` at the destination
  (a full re-hash of the whole image on every push).

* ``push_delta`` — the O(changed-bytes) path. The have-set is negotiated
  in **batched set-difference exchanges** (``DeltaReceiver.negotiate``:
  every has_layer probe in one O(#layers) request; ``probe_blobs``: every
  has_blob probe in one request covering only new-content layers' chunks),
  telling the source exactly what the remote is missing *and* which missing
  layers are content-identical re-keyed clones of layers the remote already
  verified (matched by family + content checksum — the re-key table). Only
  genuinely new chunk blobs cross the wire, on a **pipelined transfer**: blob read -> send ->
  content-address verify -> write run concurrently per blob on the shared
  hash pool, with the receiving store under ``durability="batch"`` so every
  per-blob fsync coalesces into one concurrent flush at the remote
  manifest commit. Verification is **incremental**: received blobs are
  hashed exactly once (on receipt, overlapped with the transfer), re-keyed
  clones are checked by checksum equality against the layer the remote
  already holds, and only layers with genuinely new content get the deep
  membership check — the remote never re-hashes bytes it verified on an
  earlier push. ``PushStats.layers_deep_verified`` proves the "deep-verify
  only new layers" claim; CI gates it.

* ``replicate_fanout`` — the fleet form of ``push_delta``: one training
  source feeding N serving replicas. The have-set is negotiated in ONE
  round (every replica answers the same O(#layers) request; the answers
  are unioned into a single plan), each changed blob is read from the
  source store exactly once and broadcast to every replica missing it,
  and failures are isolated per replica (``ReplicaResult``) so a sick or
  slow destination never blocks the healthy ones — a clean retry
  converges it. ``push_delta`` itself is the N=1 special case.

* ``RelayNode`` — the multi-hop form: one store that is a
  ``DeltaReceiver`` toward its parent and a fan-out source toward its
  children (trainer -> M relays -> N edge followers each). The parent's
  delta header seeds the child have-set union, so a blob received once at
  the relay is forwarded straight from the wire buffer (``inflight``) or
  read locally exactly once (``commit`` mode / stale children) — never
  re-read or re-hashed per child — and a child only ever commits after
  its relay committed.

The trust boundary is **cross-image** (one content-addressed blob
universe per store): "held" means reachable from a committed manifest of
ANY image, so negotiation, the re-key table, blob probes and commit-time
vouching all answer from the whole namespace — pushing a fine-tune to a
replica that only holds the base image transfers just the adapter deltas
(see ``LayerStore.holdings_index``; docs/ARCHITECTURE.md spells out the
held/committed/vouched model). The mutation gate and orphan
re-verification keep their exact semantics across images: a committed id
is immutable no matter which image committed it, and an uncommitted
on-disk blob/descriptor is never vouched for by a sibling image — only a
re-hash can adopt it.

``export_delta``/``import_delta`` are the offline (``docker save``-style)
form of the same protocol: a self-checking ``DeltaBundle`` byte string
computed against a base tag instead of a live have-set (``import_delta``
at a ``RelayNode`` re-fans the bundle to an edge tier).
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..ft.faults import CrashInjected, fault_point
from ..ft.retry import RetryHealth, RetryPolicy
from .chunker import hash_pool, sha256_hex
from .delta import (BundleEntry, BundleIndex, DeltaBundle, DeltaFormatError,
                    compose_delta_records, decode_delta, decode_index,
                    encode_delta, encode_index)
from .diff import diff_manifests
from .manifest import (ImageConfig, LayerDescriptor, Manifest, chain_checksum,
                       content_checksum, dumps, history_delta_chain, new_uuid)
from .store import LayerStore


class PushRejected(RuntimeError):
    pass


@dataclass
class PushStats:
    blobs_sent: int = 0
    blobs_dedup: int = 0
    layers_sent: int = 0
    layers_dedup: int = 0
    # bytes_sent is EVERYTHING on the wire: blob payloads + layer
    # descriptors + manifest/config (+ the negotiation exchange for the
    # delta path) — true wire amplification, not just payload.
    bytes_sent: int = 0
    bytes_payload: int = 0       # blob payload bytes only
    bytes_meta: int = 0          # descriptor + manifest/config (+ have-set)
    bytes_deduped: int = 0       # payload bytes NOT resent thanks to dedup
    wall_s: float = 0.0
    # Incremental-verification accounting (delta path; seed push re-hashes
    # the whole image so its deep count is every layer).
    layers_deep_verified: int = 0
    layers_rekey_verified: int = 0
    blobs_hashed_remote: int = 0


def push(src: LayerStore, dst: LayerStore, name: str, tag: str) -> PushStats:
    """Seed baseline: O(image) walk + full deep re-verification at dst."""
    stats = PushStats()
    t0 = time.perf_counter()
    problems = src.verify_image(name, tag, deep=False)
    if problems:
        raise PushRejected(f"source image fails verification: {problems}")
    manifest, config = src.read_image(name, tag)

    total_payload = 0
    for lid in manifest.layer_ids:
        layer = src.read_layer(lid)
        total_payload += layer.nbytes
        if dst.has_layer(lid):
            existing = dst.read_layer(lid)
            if existing.checksum != layer.checksum:
                # The paper's exact failure mode: same id, diverged content.
                raise PushRejected(
                    f"layer {lid}: remote holds a different checksum trace "
                    "for this id (in-place mutation without a new id?)")
            stats.layers_dedup += 1
        else:
            stats.layers_sent += 1
        for rec in layer.records:
            for h in rec.chunks:
                if dst.has_blob(h):
                    stats.blobs_dedup += 1
                else:
                    data = src.read_blob(h)
                    dst.write_blob(h, data)
                    stats.blobs_sent += 1
                    stats.bytes_payload += len(data)
        # the seed path resends EVERY descriptor, dedup'd or not
        data = dumps(layer.to_json()).encode()
        stats.bytes_meta += len(data)
        dst.write_layer(layer, encoded=data)
    stats.bytes_meta += len(dumps(manifest.to_json()).encode())
    stats.bytes_meta += len(dumps(config.to_json()).encode())
    dst.write_image(manifest, config)

    problems = dst.verify_image(name, tag, deep=True)
    stats.layers_deep_verified = len(manifest.layer_ids)
    if problems:
        raise PushRejected(f"post-push verification failed: {problems}")
    stats.bytes_sent = stats.bytes_payload + stats.bytes_meta
    stats.bytes_deduped = total_payload - stats.bytes_payload
    stats.wall_s = time.perf_counter() - t0
    return stats


def pull(src: LayerStore, dst: LayerStore, name: str, tag: str) -> PushStats:
    return push(src, dst, name, tag)


# --------------------------------------------------------------------------
# Delta protocol
# --------------------------------------------------------------------------

@dataclass
class HaveSet:
    """The remote's answer to ONE negotiation request: what it is missing,
    plus the re-key table for missing layers it can prove content-identical
    to layers it already holds."""

    missing_layers: List[str] = field(default_factory=list)
    missing_blobs: Set[str] = field(default_factory=set)
    held_checksums: Dict[str, str] = field(default_factory=dict)
    rekey: Dict[str, str] = field(default_factory=dict)
    exchange_bytes: int = 0      # request+response size (counted as meta)


def _stamp_dedup(stats: PushStats, total_refs: int, total_payload: int,
                 t0: float) -> None:
    """Post-commit dedup accounting from record metadata (no per-blob
    stats): everything the image references that did NOT cross the wire.
    Shared by every fan-out tier so the books can't drift apart."""
    stats.blobs_dedup = total_refs - stats.blobs_sent
    stats.bytes_deduped = total_payload - stats.bytes_payload
    stats.wall_s = time.perf_counter() - t0


def _gate_mutations(layer_meta: Dict[str, Tuple[str, str]],
                    held_checksums: Dict[str, str], who: str) -> None:
    """The in-place-mutation gate, shared by every tier: a destination
    holding one of the image's layer ids with a DIVERGED checksum is the
    paper's exact failure mode — rejected before any byte moves."""
    for lid, held in held_checksums.items():
        if layer_meta[lid][1] != held:
            raise PushRejected(
                f"layer {lid}: {who} holds a different checksum trace "
                "for this id (in-place mutation without a new id?)")


class _BatchScope:
    """Hold the receiving store in durability="batch" for the lifetime of a
    push so per-blob fsyncs coalesce at the remote manifest commit."""

    def __init__(self, store: LayerStore):
        self.store = store
        self._prev: Optional[str] = None

    def __enter__(self):
        self._prev = self.store.durability
        self.store.durability = "batch"
        return self

    def __exit__(self, exc_type, exc, tb):
        # write_image (the commit) already flushed deferred fsyncs, so this
        # is a no-op after a committed push. After a FAILED one (exception
        # here, or a per-replica failure captured by the fan-out) the
        # push's blobs are on disk but un-fsynced — and a later push's
        # ``probe_blobs`` orphan re-hash would ADOPT them as verified
        # without ever scheduling the fsync it skipped. Flush them before
        # leaving the scope: a crash-mid-batch must never leave bytes that
        # look adoptable but were never made durable.
        self.store.sync_for_commit()
        self.store.durability = self._prev
        return False


class DeltaReceiver:
    """The remote endpoint of a delta push.

    Wire ops: ``negotiate`` (one set-difference exchange), ``receive_layer``
    / ``receive_blob`` (streamed; blobs are content-address-verified on
    receipt — the only time new bytes are ever hashed), and ``commit``
    (incremental verification + the manifest rename). A crash anywhere
    before ``commit`` leaves the remote's previous tag fully intact: blobs
    and descriptors are orphans until the manifest rename, exactly the
    store's normal crash model.
    """

    # Tags scanned (newest first, per image) when indexing the remote's
    # holdings: the re-key/family matches worth finding live in the most
    # recent tags; scanning fewer tags only costs extra deep verification,
    # never correctness — and keeps negotiate O(images x window), not
    # O(push history).
    TAG_WINDOW = 8

    def __init__(self, store: LayerStore):
        self.store = store
        self._stats_lock = threading.Lock()   # receive_blob runs on a pool
        self.begin_push()

    def begin_push(self) -> None:
        """Reset per-push state. ``push_delta``/``replicate_fanout`` build
        fresh receivers for plain stores, but a long-lived receiver (a
        ``RelayNode`` reused across polls/retries, a receiver handed to
        ``import_delta`` twice) must be re-armed here at the START of each
        push so one push's verified-blob set or stats never vouch for the
        next. Deliberately NOT called from ``negotiate``: the
        ``negotiations`` counter must keep counting across a whole push so
        ``FanoutStats.negotiation_rounds`` measures extra rounds instead
        of tautologically reading 1."""
        self.negotiations = 0        # negotiate() exchanges this push
        self._verified_blobs: Set[str] = set()
        self._received_layers: Dict[str, LayerDescriptor] = {}
        # chunk ids referenced by COMMITTED layers of this image (built by
        # _scan_committed, pure metadata): membership here means present
        # AND verified by an earlier successful push — no stat, no hash
        self._known_chunks: Set[str] = set()
        # layer ids reachable from a committed manifest. A descriptor file
        # that exists but is NOT in this set is an orphan of a crashed push
        # — possibly torn under batch durability — and must never be
        # trusted as "held".
        self._committed_layers: Optional[Set[str]] = None
        self.rekey: Dict[str, str] = {}
        self.stats = PushStats()

    def _scan_committed(self, name: str) -> Dict[Tuple[str, str], str]:
        """Index this store's committed holdings — across EVERY image, not
        just ``name`` (the cross-image blob universe): a blob or layer
        committed under ``base`` vouches for a push of ``tenant3``, which
        is what makes replicating a fine-tune to a replica that already
        holds the base image cost O(adapter), not O(image).

        ``_committed_layers`` (the held/mutation-gate set) covers EVERY
        committed tag of EVERY image — an id referenced only by an old tag
        of a sibling image must still be protected from overwrite. Only
        the descriptor-reading work — the family index for re-key matching
        and ``_known_chunks`` — is bounded to the TAG_WINDOW newest tags
        per image; missing a match there only costs extra deep
        verification, never correctness. The scan itself is served from
        the store's cached ``holdings_index`` (invalidated at its own
        commit/removal points), so repeated pushes don't re-walk the
        namespace. ``name`` is kept for wire-protocol shape (the request
        names the image being pushed) but no longer narrows the answer."""
        del name                     # the whole namespace answers now
        idx = self.store.holdings_index(tag_window=self.TAG_WINDOW)
        # copies: the index is a shared cache entry; per-push state must
        # never alias it (receive/commit mutate _known_chunks' siblings)
        self._committed_layers = set(idx.committed_layers)
        self._known_chunks.update(idx.known_chunks)
        return dict(idx.by_family)

    # ------------------------------------------------------------ negotiate
    def negotiate(self, name: str,
                  layer_meta: Dict[str, Tuple[str, str]]) -> HaveSet:
        """The layer set-difference exchange — every has_layer probe
        batched into one request. ``layer_meta`` maps layer_id ->
        (family, content_checksum) for the manifest's layers, in manifest
        order (O(#layers) metadata, never chunk lists). Returns missing
        layers, checksums of held layers (the in-place-mutation gate runs
        against these), and the re-key table: missing layers whose
        (family, checksum) matches a layer this store already holds under
        ANY committed tag of ANY image — a fine-tune's unchanged layers
        may be vouched for by the base image's holdings, so those need no
        blob probes and no deep verification: content-checksum equality
        over the chunk-hash list proves every blob is already present and
        verified, whatever image name committed it.

        "Held" means reachable from a COMMITTED manifest (of any image) —
        a descriptor orphaned by a crashed earlier push is reported
        missing, so it gets re-received and re-verified rather than
        trusted.

        Crash/retry contract: pure metadata — no store mutation, so a
        crash during (or after) negotiate leaves nothing to clean up and
        a retry simply renegotiates. Counters: increments
        ``negotiations`` (surfaced as ``FanoutStats.negotiation_rounds``,
        CI-gated to 1 per push) and accounts the request+response size in
        ``HaveSet.exchange_bytes`` (folded into ``PushStats.bytes_meta``).
        """
        have = HaveSet()
        fault_point("wire.negotiate", self.store.root)
        self.negotiations += 1
        by_family = self._scan_committed(name)

        for lid, (family, checksum) in layer_meta.items():
            if lid in self._committed_layers and self.store.has_layer(lid):
                have.held_checksums[lid] = self.store.read_layer(lid).checksum
                continue
            have.missing_layers.append(lid)
            twin = by_family.get((family, checksum))
            if twin is not None:
                have.rekey[lid] = twin
        # request = (lid, family, checksum) rows; response = the sets
        have.exchange_bytes = sum(
            len(lid) + len(fam) + len(cs)
            for lid, (fam, cs) in layer_meta.items())
        have.exchange_bytes += sum(
            len(lid) + len(cs) for lid, cs in have.held_checksums.items())
        have.exchange_bytes += sum(len(x) for x in have.missing_layers)
        have.exchange_bytes += sum(len(a) + len(b)
                                   for a, b in have.rekey.items())
        self.rekey = dict(have.rekey)
        return have

    def probe_blobs(self, chunk_ids: Sequence[str]) -> Set[str]:
        """The blob set-difference exchange — every has_blob probe batched
        into one request. Callers only probe chunks of genuinely-new-content
        layers (re-keyed clones were already settled by ``negotiate``), so
        this message is O(changed-layer chunks), not O(image chunks); and
        chunks already referenced by committed layers — of ANY image, the
        cross-image universe — are answered from metadata
        (``_known_chunks``) without touching the filesystem.

        A blob that exists on disk but is NOT committed-known under any
        image is an orphan of a crashed push — possibly torn (batch
        durability defers fsyncs). It is re-hashed here: intact orphans
        are adopted as verified (and their deferred fsync re-armed); torn
        ones are deleted (unreferenced, so safe) and reported missing so
        the pusher resends them. Adoption is strictly content-addressed —
        a sibling image being committed never vouches for an uncommitted
        blob; only the re-hash does. Either way a retry after a crash
        converges; the cost is O(orphaned chunks), zero on a clean store.

        Crash/retry contract: the only mutations are deleting torn
        orphans (unreferenced by construction) and re-arming fsyncs —
        both idempotent; a crash mid-probe loses nothing a retry can't
        redo. Counters: adopted orphans increment
        ``PushStats.blobs_hashed_remote``; probe traffic lands in
        ``bytes_meta``."""
        fault_point("wire.probe_blobs", self.store.root)
        missing: Set[str] = set()
        for h in chunk_ids:
            if h in self._known_chunks or h in self._verified_blobs:
                continue
            if not self.store.has_blob(h):
                missing.add(h)
                continue
            if sha256_hex(self.store.read_blob(h)) == h:
                self._verified_blobs.add(h)
                self.stats.blobs_hashed_remote += 1
                # adoption must re-arm the fsync the crashed writer never
                # issued — intact-on-read does not mean durable-on-disk
                self.store.ensure_blob_durable(h)
            else:
                self.store.drop_blob(h)      # torn orphan: resend
                missing.add(h)
        self.stats.bytes_meta += sum(len(h) for h in chunk_ids)
        self.stats.bytes_meta += sum(len(h) for h in missing)
        return missing

    # ------------------------------------------------------------- receive
    def receive_layer(self, layer: LayerDescriptor,
                      encoded: Optional[bytes] = None) -> int:
        """A committed descriptor is IMMUTABLE at this store — whichever
        image committed it: receiving the same id with a diverged checksum
        is the in-place mutation the gate exists for (this is what keeps
        the offline ``import_delta`` path as safe as the negotiated one,
        and what stops a tenant push from rewriting a base image's layer
        in place); an identical re-send is a no-op. ``encoded`` lets a
        fan-out source serialize each descriptor once for every replica
        (must be ``dumps(layer.to_json())``). A crash after the write
        leaves an orphan descriptor the next push re-verifies, never
        trusts; counters: ``PushStats.layers_sent`` / ``bytes_meta``."""
        fault_point("wire.receive_layer",
                    f"{self.store.root}:{layer.layer_id}")
        if self._committed_layers is not None and \
                layer.layer_id in self._committed_layers and \
                self.store.has_layer(layer.layer_id):
            held = self.store.read_layer(layer.layer_id)
            if held.checksum != layer.checksum:
                raise PushRejected(
                    f"layer {layer.layer_id}: already committed here with a "
                    "different checksum trace (in-place mutation without a "
                    "new id?)")
            return 0
        data = encoded if encoded is not None \
            else dumps(layer.to_json()).encode()
        self._received_layers[layer.layer_id] = layer
        self.store.write_layer(layer, encoded=data)
        self.stats.layers_sent += 1
        self.stats.bytes_meta += len(data)
        return len(data)

    def receive_blob(self, h: str, data: bytes) -> int:
        """Content-address verification happens HERE, overlapped with the
        transfer — the only time a pushed byte is ever hashed remotely.

        Crash/retry contract: a mismatching payload raises ``PushRejected``
        before the blob is linked in; a crash after the write leaves an
        orphan blob that the next push's ``probe_blobs`` re-hashes (adopt
        or drop+resend) — received bytes are never durable-trusted until
        the commit point flushes them. Thread-safe (fan-out receives run
        on the shared hash pool). Counters: ``PushStats.blobs_sent``,
        ``blobs_hashed_remote``, ``bytes_payload``."""
        data = fault_point("wire.receive_blob",
                           f"{self.store.root}:{h}", data)
        if sha256_hex(data) != h:
            raise PushRejected(f"blob {h[:12]}: payload does not match its "
                               "content address (corrupt transfer)")
        self.store.write_blob(h, data)
        with self._stats_lock:
            self._verified_blobs.add(h)
            self.stats.blobs_hashed_remote += 1
            self.stats.blobs_sent += 1
            self.stats.bytes_payload += len(data)
        return len(data)

    def _blob_ok(self, h: str) -> bool:
        """A chunk passes if it was verified on receipt this push, is
        referenced by a committed (earlier-verified) layer, or — the
        crashed-push orphan case — exists on disk AND re-hashes to its
        address (adopted into the verified set, counted once)."""
        if h in self._verified_blobs or h in self._known_chunks:
            return True
        if not self.store.has_blob(h):
            return False
        if sha256_hex(self.store.read_blob(h)) != h:
            return False
        self._verified_blobs.add(h)
        self.stats.blobs_hashed_remote += 1
        self.store.ensure_blob_durable(h)    # adopted orphan: re-arm fsync
        return True

    # -------------------------------------------------------------- commit
    def commit(self, manifest: Manifest, config: ImageConfig) -> PushStats:
        """Incremental verification, then the manifest rename.

        * committed pre-existing layer: checksum must equal the incoming
          config lock (same id + diverged checksum = the paper's in-place
          mutation — rejected). Its blobs were verified when ITS push
          committed; never re-hashed.
        * re-keyed clone: received descriptor's records must hash (metadata
          content checksum) to the SAME checksum as the already-held twin —
          content identical, so every blob is already present and verified.
        * new-content layer (received, or an on-disk orphan of a crashed
          push): deep incremental check — records must match checksum and
          config lock, and every chunk must pass ``_blob_ok`` (verified on
          receipt, committed-known, or re-hashed now). Outside the
          crash-recovery case no byte is ever hashed twice.
        * all layers: the chain checksums are re-keyed and re-checked
          link by link (metadata-only), so the re-key walk the source did
          is independently recomputed at the remote.

        Pre-existing layers and re-key twins may have been committed under
        a DIFFERENT image name (the cross-image universe) — the checks are
        identical either way, because they compare content checksums, not
        namespaces; a twin is only trusted if ITS id is committed-reachable
        somewhere, never because its descriptor file merely exists.

        Crash/retry contract: every verification failure raises
        ``PushRejected`` BEFORE ``write_image`` — the store's previous
        tags stay authoritative, and a retry re-pushes through the normal
        orphan-recovery path. The manifest rename inside ``write_image``
        is the single commit point (deferred batch fsyncs flush just
        before it). Counters: ``layers_dedup`` / ``layers_rekey_verified``
        / ``layers_deep_verified`` split the verification classes —
        CI gates that only genuinely-new-content layers are deep-verified.
        """
        stats = self.stats
        fault_point("wire.commit", self.store.root)
        if self._committed_layers is None:       # offline path: no negotiate
            self._scan_committed(manifest.name)
        parent_chain: Optional[str] = None
        for lid in manifest.layer_ids:
            received = self._received_layers.get(lid)
            if received is None and lid in self._committed_layers and \
                    self.store.has_layer(lid):
                layer = self.store.read_layer(lid)
                want = config.layer_checksums.get(lid)
                if layer.checksum != want:
                    raise PushRejected(
                        f"layer {lid}: remote holds a different checksum "
                        "trace for this id (in-place mutation without a "
                        "new id?)")
                stats.layers_dedup += 1
            else:
                if received is None:
                    # an on-disk descriptor NOT reachable from a committed
                    # manifest is an orphan of a crashed push: re-verify it
                    # like a received layer, never trust it
                    if not self.store.has_layer(lid):
                        raise PushRejected(f"layer {lid}: neither received "
                                           "nor already held")
                    layer = self.store.read_layer(lid, use_cache=False)
                else:
                    layer = received
                if content_checksum(layer.records) != layer.checksum or \
                        config.layer_checksums.get(lid) != layer.checksum:
                    raise PushRejected(
                        f"layer {lid}: received records do not match the "
                        "declared checksum/lock")
                # a re-key twin is only trustworthy if IT was verified by a
                # committed push — an orphan descriptor must not vouch
                twin_id = self.rekey.get(lid)
                twin = (self.store.read_layer(twin_id)
                        if twin_id and twin_id in self._committed_layers
                        and self.store.has_layer(twin_id)
                        else None)
                if twin is not None and twin.checksum == layer.checksum:
                    # content-identical clone of an already-verified layer
                    stats.layers_rekey_verified += 1
                else:
                    for rec in layer.records:
                        for h in rec.chunks:
                            if not self._blob_ok(h):
                                raise PushRejected(
                                    f"layer {lid}: missing or corrupt "
                                    f"blob {h[:12]}")
                    stats.layers_deep_verified += 1
            expected = chain_checksum(parent_chain, layer.checksum,
                                      layer.instruction.text)
            if expected != layer.chain or \
                    config.layer_chains.get(lid) != layer.chain:
                raise PushRejected(f"layer {lid}: chain re-key mismatch")
            parent_chain = layer.chain

        cfg_bytes = dumps(config.to_json()).encode()
        man_bytes = dumps(manifest.to_json()).encode()
        stats.bytes_meta += len(cfg_bytes) + len(man_bytes)
        # the manifest rename: batch-durability fsyncs coalesce here
        self.store.write_image(manifest, config)
        stats.bytes_sent = stats.bytes_payload + stats.bytes_meta
        return stats


_TRANSFER_BATCH = 32    # blobs in flight per pipeline wave


@dataclass
class ReplicaResult:
    """One destination's outcome in a fan-out: its PushStats on success,
    the captured failure otherwise. Failures are ISOLATED — a replica that
    rejects, corrupts a transfer or dies never blocks the others; a later
    ``replicate_fanout`` retry converges it (orphan blobs/descriptors are
    re-verified by the normal negotiate/probe crash-recovery path).

    ``stats`` is only set for replicas that COMMITTED. A replica that
    failed mid-push still reports what actually crossed the wire before it
    dropped out in ``stats_partial`` — bytes of waves never sent to it are
    never counted anywhere; a within-run retry (``retry=`` on
    ``replicate_fanout``) that later converges it sets ``stats`` to the
    SUCCESSFUL attempt's books while ``stats_partial`` keeps the first
    failure's, so "the retry paid only the remainder" is checkable.
    ``health`` records the retry loop's outcome (attempts, backoff,
    quarantine) whenever one ran. ``children`` nests the downstream tier's
    outcome when this replica is a ``RelayNode``."""

    stats: Optional[PushStats] = None
    error: Optional[str] = None
    exception: Optional[BaseException] = None
    stats_partial: Optional[PushStats] = None
    children: Optional["FanoutStats"] = None
    health: Optional[RetryHealth] = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass
class FanoutStats:
    """What one fan-out replication actually cost the SOURCE, plus the
    per-replica outcomes. ``negotiation_rounds`` and ``source_blob_reads``
    are the paper-style structural claims CI gates: the source walks its
    layer metadata once and reads each changed blob from its store exactly
    once, no matter how many replicas are behind."""

    replicas: List[ReplicaResult] = field(default_factory=list)
    negotiation_rounds: int = 0
    source_blob_reads: int = 0
    # unique blobs actually SHIPPED to at least one replica. Counted at
    # ship time, never precomputed: when a replica drops out between
    # transfer waves, blobs whose only taker died are neither read nor
    # counted — source_blob_reads == blobs_broadcast stays exact.
    blobs_broadcast: int = 0
    wall_s: float = 0.0
    # Self-healing accounting (retry= passed): replica indices that
    # exhausted their attempts this run (their ReplicaResult.health holds
    # the structured record), and the total extra attempts spent across
    # the fleet. A quarantined replica is left for the NEXT replication
    # cycle (or an operator) — never retried forever in-line.
    quarantined: List[int] = field(default_factory=list)
    retries_spent: int = 0

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.replicas)

    @property
    def n_ok(self) -> int:
        return sum(1 for r in self.replicas if r.ok)

    @property
    def majority_ok(self) -> bool:
        """Graceful degradation floor: more than half the fleet committed
        this tag (chaos CI asserts this under single-fault injection)."""
        return self.n_ok * 2 > len(self.replicas)

    @property
    def deep_ok(self) -> bool:
        """ok across EVERY tier: this one and, for relay replicas, the
        whole downstream topology."""
        return all(r.ok and (r.children is None or r.children.deep_ok)
                   for r in self.replicas)


def _as_receiver(r) -> "DeltaReceiver":
    """Remotes come in three shapes: a live receiver (RelayNode / reused
    DeltaReceiver), a LayerStore, or a filesystem path."""
    if isinstance(r, DeltaReceiver):
        return r
    return DeltaReceiver(r if isinstance(r, LayerStore) else
                         LayerStore(str(r)))


class RelayNode(DeltaReceiver):
    """A relay tier: one store that is simultaneously a ``DeltaReceiver``
    (pulls a delta from its parent) and a fan-out source (re-fans the SAME
    negotiated plan to its children).

    The parent's delta header seeds the child tier: ``negotiate`` answers
    the parent with the relay's own have-set AND forwards the identical
    O(#layers) request to every child, and ``probe_blobs`` re-uses the
    parent's chunk probe list as the child probe — the relay never
    re-derives negotiation from scratch, and every tier still pays exactly
    one negotiation round. The union of the child answers splits into two
    plans:

    * **from-parent** blobs (the relay is missing them too): each one
      arrives exactly once via ``receive_blob`` — content-address-verified
      on receipt — and, with ``source="inflight"`` (the default), is
      forwarded to every child missing it straight from the wire buffer:
      zero local reads, zero relay-side re-hashing, bytes stream downstream
      while the relay's own pull is still in flight. ``source="commit"``
      defers the forward until the relay has committed (one local read per
      blob, still never one per child).
    * **serve-local** blobs (the relay already holds them — children
      staler than the relay, or re-key/dedup twins): read from the relay's
      store exactly ONCE each at fan time and broadcast to every child
      that lacks them, no matter how many children there are.

    Atomicity is tiered: children receive bytes early, but a child
    ``commit`` only ever runs AFTER the relay's own commit succeeded —
    a relay that fails (or dies) mid-pull leaves every child at its
    previous tag with only orphan blobs behind, and a fleet-wide retry
    converges through the normal orphan re-verification path. Child
    failures are isolated per child (``fan.replicas``) and never poison
    the relay's own pull. Children may themselves be ``RelayNode``s —
    tiers nest arbitrarily deep.

    **Retention leases** close the ROADMAP prune-vs-lagging-child race: at
    ``negotiate`` the relay takes a ref-count lease (per child, TTL
    ``lease_ttl_s``) on every tag its store currently holds — across
    EVERY image, since cross-image holdings can vouch for the pull — the
    base revisions a lagging child's delta resumes from. Retention
    (``ckpt.prune_steps`` -> ``LayerStore.remove_image``) refuses to
    collect a leased tag. A child's leases are released the moment it
    COMMITS (it no longer needs any base) and simply expire if the child
    died — so a dead edge can never pin the relay's disk forever, and a
    live lagging one can never have its base pruned out from under it.

    ``retry=`` (a ``ft.RetryPolicy``) makes the re-fan self-healing: a
    child that failed its first fan is re-pushed from the relay's own
    committed store with backoff, resuming from whatever bytes already
    landed (orphan adoption); a child that exhausts its attempts is
    quarantined on ``fan.quarantined`` with its ``RetryHealth``.

    Crash/retry contract in one line: nothing downstream of a tier ever
    commits unless that tier committed first, and every partial state a
    crash can leave (orphan blobs/descriptors, unexpired leases, unflushed
    batch fsyncs) is re-verified or expires on the next push — the chaos
    suite (tests/test_chaos.py) drives every fault point through exactly
    these counters: ``fan.negotiation_rounds``, ``inflight_blobs``,
    ``local_blob_reads``, per-child ``ReplicaResult.stats(_partial)`` and
    ``RetryHealth``.
    """

    LEASE_TTL_S = 600.0

    def __init__(self, store, children: Sequence = (),
                 source: str = "inflight",
                 retry: Optional[RetryPolicy] = None,
                 lease_ttl_s: float = LEASE_TTL_S):
        if source not in ("inflight", "commit"):
            raise ValueError(f"source must be 'inflight' or 'commit', "
                             f"got {source!r}")
        if isinstance(children, (str, bytes)):
            # a bare path would be iterated per CHARACTER, building one
            # junk store per char — always a caller bug
            raise TypeError("children must be a sequence of stores/paths/"
                            f"receivers, not a bare path: {children!r}")
        super().__init__(store if isinstance(store, LayerStore)
                         else LayerStore(str(store)))
        self.children: List[DeltaReceiver] = [_as_receiver(c)
                                              for c in children]
        self.source = source
        self.retry = retry
        self.lease_ttl_s = lease_ttl_s
        self._relay_lock = threading.Lock()
        self._begin_fan()

    def _lease_owner(self, i: int) -> str:
        """Stable per (this relay, child slot) across pushes and retries,
        so a retry refreshes the same lease instead of stacking new ones."""
        return f"relay-{id(self):x}/child-{i}"

    def begin_push(self) -> None:
        super().begin_push()
        # __init__ order: the first begin_push runs before children exist
        if hasattr(self, "children"):
            self._begin_fan()
            for child in self.children:
                child.begin_push()

    def override_source(self, mode: str) -> None:
        """Set THIS push's streaming mode for the whole subtree. The
        node's configured ``source`` is untouched — a later push without
        an override gets the configured mode back — and the override is
        cleared by the next ``begin_push``."""
        self._push_source = mode
        for child in self.children:
            if isinstance(child, RelayNode):
                child.override_source(mode)

    @property
    def effective_source(self) -> str:
        return self._push_source or self.source

    def _begin_fan(self) -> None:
        self._push_source: Optional[str] = None   # per-push mode override
        self.fan = FanoutStats(
            replicas=[ReplicaResult() for _ in self.children])
        self._child_missing: List[List[str]] = [[] for _ in self.children]
        # blob -> child indices. _inflight_want blobs arrive from the
        # parent; _local_want blobs are served from the relay's own store.
        self._inflight_want: Dict[str, Set[int]] = {}
        self._local_want: Dict[str, Set[int]] = {}
        self._forwarded: Set[str] = set()
        self.inflight_blobs = 0      # unique blobs forwarded pre-commit
        self.local_blob_reads = 0    # local store reads during the fan

    def all_stores(self):
        """Every store in this subtree (for batch-durability scoping)."""
        yield self.store
        for child in self.children:
            if isinstance(child, RelayNode):
                yield from child.all_stores()
            else:
                yield child.store

    def _child_ok(self, i: int) -> bool:
        return self.fan.replicas[i].error is None

    def _fail_child(self, i: int, exc: BaseException) -> None:
        with self._relay_lock:
            if self.fan.replicas[i].error is None:
                self.fan.replicas[i].error = f"{type(exc).__name__}: {exc}"
                self.fan.replicas[i].exception = exc
                self.fan.replicas[i].stats_partial = \
                    self.children[i].stats

    # ------------------------------------------------------------ negotiate
    def negotiate(self, name: str,
                  layer_meta: Dict[str, Tuple[str, str]]) -> HaveSet:
        """Answer the parent with the relay's own have-set, then seed every
        child with the SAME request. Child-missing layers whose content the
        relay can already serve (committed here, or content-identical to a
        committed re-key twin) get their chunk lists probed at the child
        now — those blobs never need the parent."""
        have = super().negotiate(name, layer_meta)
        # the relay's current tags are the base revisions a lagging child
        # resumes from: lease them per child BEFORE any plan is made, so a
        # concurrent/interleaved prune can never collect a base a child
        # still negotiates against. Cross-image holdings vouch now, so the
        # lease set spans EVERY image the relay holds — a child pulling
        # ``tenant3`` may be negotiating against blobs only ``base``
        # reaches. Released at that child's commit; expires if the child
        # dies mid-pull.
        held_tags = [(img, t) for img in self.store.list_images()
                     for t in self.store.list_tags(img)]
        for i in range(len(self.children)):
            for img, t in held_tags:
                self.store.acquire_lease(img, t, self._lease_owner(i),
                                         self.lease_ttl_s)
        for i, child in enumerate(self.children):
            try:
                ch = child.negotiate(name, layer_meta)
                child.stats.bytes_meta += ch.exchange_bytes
                # the mutation gate, per child, before any byte moves
                _gate_mutations(layer_meta, ch.held_checksums,
                                "child replica")
                self._child_missing[i] = list(ch.missing_layers)
                servable: Set[str] = set()
                for lid in ch.missing_layers:
                    if lid in ch.rekey:
                        continue      # child proves it holds the content
                    if self._committed_layers and \
                            lid in self._committed_layers and \
                            self.store.has_layer(lid):
                        src_lid = lid
                    else:
                        # relay re-keys lid to a committed twin: content
                        # identical, so the twin's chunk list IS lid's
                        src_lid = have.rekey.get(lid)
                    if src_lid is None or not self.store.has_layer(src_lid):
                        continue      # arrives from the parent instead
                    for rec in self.store.read_layer(src_lid).records:
                        servable.update(rec.chunks)
                if servable:
                    for h in child.probe_blobs(sorted(servable)):
                        self._local_want.setdefault(h, set()).add(i)
            except Exception as e:  # noqa: BLE001
                self._fail_child(i, e)
        return have

    def probe_blobs(self, chunk_ids: Sequence[str]) -> Set[str]:
        """The parent's probe list (chunks of relay-missing content
        layers) doubles as the child probe — the delta header seeding the
        child have-set union. A chunk a child lacks routes in-flight if the
        parent is about to send it, serve-local if the relay already holds
        it (cross-layer dedup)."""
        missing = super().probe_blobs(chunk_ids)
        for i, child in enumerate(self.children):
            if not self._child_ok(i):
                continue
            try:
                lacks = child.probe_blobs(chunk_ids)
            except Exception as e:  # noqa: BLE001
                self._fail_child(i, e)
                continue
            for h in lacks:
                want = self._inflight_want if h in missing \
                    else self._local_want
                want.setdefault(h, set()).add(i)
        return missing

    # ------------------------------------------------------------- receive
    def receive_blob(self, h: str, data: bytes) -> int:
        """Verify + write locally (the relay's own single hash of the
        byte), then — in-flight mode — forward the SAME wire buffer to
        every child missing it: no local re-read, no relay-side re-hash;
        each child runs its own verify-on-receipt."""
        n = super().receive_blob(h, data)
        if self.effective_source == "inflight" and h in self._inflight_want:
            with self._relay_lock:
                first = h not in self._forwarded
                self._forwarded.add(h)
                targets = [i for i in sorted(self._inflight_want[h])
                           if self.fan.replicas[i].error is None]
                if first and targets:
                    self.inflight_blobs += 1
            for i in targets:
                try:
                    self.children[i].receive_blob(h, data)
                except Exception as e:  # noqa: BLE001
                    self._fail_child(i, e)
        return n

    # -------------------------------------------------------------- commit
    def commit(self, manifest: Manifest, config: ImageConfig) -> PushStats:
        """The relay's own incremental verification + manifest rename
        first; only then does the child tier finalize — a failed or killed
        relay pull means no child ever commits."""
        stats = super().commit(manifest, config)
        self._fan_children(manifest, config)
        return stats

    def _layer_for(self, lid: str) -> LayerDescriptor:
        received = self._received_layers.get(lid)
        return received if received is not None else self.store.read_layer(lid)

    def _fan_children(self, manifest: Manifest, config: ImageConfig) -> None:
        t0 = time.perf_counter()
        # a relay that dies at the re-fan point: its own tag committed,
        # children receive nothing this round (retry/next poll converges)
        fault_point("relay.fan", self.store.root)
        # blobs still owed to children: the serve-local plan plus any
        # in-flight blobs not yet forwarded (source="commit", or a child
        # plan learned after the blob passed through). Blob-major: ONE
        # local read per blob, broadcast to every child that lacks it.
        pending: Dict[str, Set[int]] = {}
        for h, idxs in self._local_want.items():
            pending.setdefault(h, set()).update(idxs)
        for h, idxs in self._inflight_want.items():
            if h not in self._forwarded:
                pending.setdefault(h, set()).update(idxs)
        for h in sorted(pending):
            targets = [i for i in sorted(pending[h]) if self._child_ok(i)]
            if not targets:
                continue
            try:
                data = self.store.read_blob(h)
            except OSError as e:
                # a locally-unreadable blob (retention race, bad sector)
                # fails only the children that needed THAT blob — the
                # relay already committed and the other children proceed
                for i in targets:
                    self._fail_child(i, e)
                continue
            self.local_blob_reads += 1
            for i in targets:
                try:
                    self.children[i].receive_blob(h, data)
                except Exception as e:  # noqa: BLE001
                    self._fail_child(i, e)

        # image-wide totals for per-child dedup accounting (metadata only;
        # every descriptor is local post-commit)
        total_refs = total_payload = 0
        for lid in manifest.layer_ids:
            layer = self._layer_for(lid)
            total_refs += sum(len(rec.chunks) for rec in layer.records)
            total_payload += layer.nbytes

        encoded: Dict[str, bytes] = {}   # descriptors encoded ONCE for all
        for i, child in enumerate(self.children):
            if not self._child_ok(i):
                continue
            try:
                for lid in self._child_missing[i]:
                    layer = self._layer_for(lid)
                    if lid not in encoded:
                        encoded[lid] = dumps(layer.to_json()).encode()
                    child.receive_layer(layer, encoded=encoded[lid])
                st = child.commit(manifest, config)
                _stamp_dedup(st, total_refs, total_payload, t0)
                self.fan.replicas[i].stats = st
                if isinstance(child, RelayNode):
                    self.fan.replicas[i].children = child.fan
                # committed: this child needs no base revision anymore —
                # release the whole cross-image lease set it pinned
                self.store.release_lease(None, self._lease_owner(i))
            except Exception as e:  # noqa: BLE001
                self._fail_child(i, e)
        if self.retry is not None:
            _retry_failed(self.store, self.children, self.fan,
                          manifest.name, manifest.tag, None, self.retry,
                          on_converged=lambda i: self.store.release_lease(
                              None, self._lease_owner(i)))
        self.fan.negotiation_rounds = max(
            (c.negotiations for c in self.children), default=0)
        self.fan.source_blob_reads = self.local_blob_reads
        self.fan.blobs_broadcast = self.inflight_blobs + self.local_blob_reads
        self.fan.wall_s = time.perf_counter() - t0


def _retry_failed(src: LayerStore, receivers: Sequence, fan: FanoutStats,
                  name: str, tag: str, source: Optional[str],
                  retry: RetryPolicy, on_converged=None) -> None:
    """Self-heal the failed replicas of a fan-out WITHIN the run: each one
    gets up to ``retry.max_attempts - 1`` further single-destination pushes
    (the main pass was attempt 1) with exponential backoff between them.
    Every retry resumes from the replica's actual partial progress — blobs
    that landed before the failure are adopted by the orphan re-hash at
    ``probe_blobs``, never resent — so a retry pays only the remainder.
    A replica that exhausts its attempts (or the deadline) is QUARANTINED:
    indexed on ``fan.quarantined`` with the structured ``RetryHealth`` on
    its ``ReplicaResult``, left for the next replication cycle."""
    for i, rep in enumerate(fan.replicas):
        if rep.ok:
            continue
        health = RetryHealth(attempts=1)
        if rep.error:
            health.errors.append(rep.error)
        t0 = time.monotonic()
        for n in range(1, retry.max_attempts):
            delay = retry.backoff(n - 1)
            if retry.deadline_s is not None and \
                    time.monotonic() - t0 + delay > retry.deadline_s:
                health.deadline_exceeded = True
                break
            time.sleep(delay)
            health.backoff_total_s += delay
            health.attempts += 1
            health.retries += 1
            fan.retries_spent += 1
            try:
                sub = replicate_fanout(src, [receivers[i]], name, tag,
                                       source=source)
                r0 = sub.replicas[0]
                if not r0.ok:
                    raise r0.exception if r0.exception is not None \
                        else RuntimeError(r0.error)
            except Exception as e:      # noqa: BLE001 — retry loop
                health.record_error(e)
                rep.error = f"{type(e).__name__}: {e}"
                rep.exception = e
                continue
            rep.stats = r0.stats        # stats_partial keeps the FIRST
            rep.error = None            # failure's books: retry delta is
            rep.exception = None        # provably just the remainder
            rep.children = r0.children
            health.succeeded = True
            if on_converged is not None:
                on_converged(i)
            break
        health.wall_s = time.monotonic() - t0
        if not health.succeeded:
            health.quarantined = True
            fan.quarantined.append(i)
        rep.health = health


def replicate_fanout(src: LayerStore, remotes: Sequence,
                     name: str, tag: str,
                     source: Optional[str] = None,
                     retry: Optional[RetryPolicy] = None) -> FanoutStats:
    """Fan-out delta replication: push ``name:tag`` to N replicas with ONE
    negotiated have-set and ONE source read pass.

    * One negotiation round: every replica answers the same O(#layers)
      metadata request (``DeltaReceiver.negotiate`` + ``probe_blobs``);
      the answers are unioned into a single plan mapping each missing blob
      to the replicas that need it — replicas missing different subsets
      get per-replica send lists carved from that one plan.
    * One source read pass: each blob any replica is missing is read from
      the source store exactly once (``FanoutStats.source_blob_reads``)
      and broadcast through the pipelined read -> send -> verify -> write
      path, bounded in-flight batches keeping peak memory at O(batch);
      layer descriptors are serialized once for all replicas.
    * Per-replica isolation: negotiation, transfer and commit failures are
      captured per replica (``ReplicaResult``); healthy replicas commit
      regardless, commits run concurrently so one straggler doesn't hold
      the rest, and a clean retry converges the failed ones.

    ``remotes`` may mix stores/paths with ``RelayNode``s — a relay pulls
    like any replica and re-fans the same plan to its own children
    (``ReplicaResult.children`` nests the downstream outcome).
    ``source="inflight"`` makes every relay stream received bytes to its
    children while this pull is still in flight; ``source="commit"``
    defers the re-fan until each relay commits; ``None`` keeps each
    relay's own configured mode.

    Replicas already holding a SIBLING image dedup against it: the
    have-set answers from each replica's whole committed namespace, so
    fanning a fresh fine-tune to replicas that hold the base image ships
    only the adapter deltas (bench_multitenant counter-proves zero
    base-blob transfers).

    Crash/retry contract: the source is read-only throughout; each
    replica's exposure is the receiver contract above (nothing visible
    before its own manifest rename, orphans re-verified on retry), so
    killing this call at ANY point leaves every replica serving its
    previous tag. With ``retry=``, failed replicas are re-pushed in-run
    with backoff, resuming from their actual partial progress; exhausted
    ones are quarantined on ``FanoutStats.quarantined``. Counters:
    ``negotiation_rounds`` (must be 1), ``source_blob_reads`` ==
    ``blobs_broadcast`` (each changed blob read exactly once),
    ``retries_spent``, and per-replica ``ReplicaResult`` books.
    """
    if source not in (None, "inflight", "commit"):
        raise ValueError(f"source must be 'inflight' or 'commit', "
                         f"got {source!r}")
    if isinstance(remotes, (str, bytes)):
        raise TypeError("remotes must be a sequence of stores/paths/"
                        f"receivers, not a bare path: {remotes!r}")
    t0 = time.perf_counter()
    problems = src.verify_image(name, tag, deep=False)   # once, not per N
    if problems:
        raise PushRejected(f"source image fails verification: {problems}")
    manifest, config = src.read_image(name, tag)
    layers = {lid: src.read_layer(lid) for lid in manifest.layer_ids}
    layer_meta = {lid: (layer.family, layer.checksum)
                  for lid, layer in layers.items()}
    total_refs = sum(len(rec.chunks) for layer in layers.values()
                     for rec in layer.records)
    total_payload = sum(layer.nbytes for layer in layers.values())

    receivers = [_as_receiver(r) for r in remotes]
    fan = FanoutStats(replicas=[ReplicaResult() for _ in receivers])
    lock = threading.Lock()

    def fail(i: int, exc: BaseException) -> None:
        with lock:
            if fan.replicas[i].error is None:
                fan.replicas[i].error = f"{type(exc).__name__}: {exc}"
                # kept with its traceback: push_delta re-raises it, and a
                # transfer-failure frame pins at most ONE blob's bytes
                fan.replicas[i].exception = exc
                # what actually crossed the wire before the drop — never
                # the waves that were skipped after it
                fan.replicas[i].stats_partial = receivers[i].stats

    def alive(i: int) -> bool:
        return fan.replicas[i].error is None

    with contextlib.ExitStack() as stack:
        for recv in receivers:
            for s in (recv.all_stores() if isinstance(recv, RelayNode)
                      else (recv.store,)):
                stack.enter_context(_BatchScope(s))

        # ---- ONE negotiation round: same request to every replica (the
        # independent exchanges run concurrently — each one scans its own
        # replica's metadata), the answers unioned into one plan
        # (blob -> replicas missing it). negotiation_rounds is MEASURED
        # from the receivers' exchange counters, not asserted.
        missing_layers: List[List[str]] = [[] for _ in receivers]
        plans: Dict[int, Set[str]] = {}
        want: Dict[str, List[int]] = {}
        pool = hash_pool()
        if pool is not None and \
                threading.current_thread().name.startswith("repro-sha"):
            # nested fan-out (relay child retry runs inside commit, which
            # may itself execute on a pool worker): block-joining the
            # shared pool from one of its own threads can deadlock on a
            # small pool, so nested pushes run inline
            pool = None

        def plan(i: int) -> None:
            try:
                recv = receivers[i]
                recv.begin_push()          # re-arm a reused receiver
                if source is not None and isinstance(recv, RelayNode):
                    # per-push override for the WHOLE subtree; cleared by
                    # the next begin_push, so the node's configured mode
                    # survives for later source=None pushes
                    recv.override_source(source)
                have = recv.negotiate(name, layer_meta)
                recv.stats.bytes_meta += have.exchange_bytes
                # the mutation gate, BEFORE any byte moves
                _gate_mutations(layer_meta, have.held_checksums, "remote")
                # blob set-difference: only new-content layers' chunks
                need = sorted({h for lid in have.missing_layers
                               if lid not in have.rekey
                               for rec in layers[lid].records
                               for h in rec.chunks})
                missing_layers[i] = list(have.missing_layers)
                plans[i] = recv.probe_blobs(need) if need else set()
            except Exception as e:  # noqa: BLE001
                fail(i, e)

        if len(receivers) > 1 and pool is not None:
            for f in [pool.submit(plan, i) for i in range(len(receivers))]:
                f.result()
        else:
            for i in range(len(receivers)):
                plan(i)
        for i in sorted(plans):
            if not alive(i):
                continue
            for h in plans[i]:
                want.setdefault(h, []).append(i)
        fan.negotiation_rounds = max(
            (r.negotiations for r in receivers), default=0)

        # ---- ONE source read pass, broadcast on the pipelined transfer:
        # one pool task per blob reads it (exactly once) and verifies +
        # writes the first replica inline — reads of other blobs overlap
        # with SHA verification exactly as the single-destination pipeline
        # always did — while the remaining replicas' receives fan out as
        # their own pool tasks (SHA releases the GIL, so N replicas verify
        # in parallel). Bounded in-flight waves keep memory at O(batch),
        # not O(delta) — and never O(N x delta).
        hashes = sorted(h for h, targets in want.items()
                        if any(alive(i) for i in targets))

        def receive(i: int, h: str, data: bytes) -> None:
            if not alive(i):
                return
            try:
                receivers[i].receive_blob(h, data)
            except Exception as e:  # noqa: BLE001
                fail(i, e)

        recv_futures: List[Future] = []

        def ship(h: str) -> None:
            targets = [i for i in want[h] if alive(i)]
            if not targets:
                return              # every taker died mid-transfer
            try:
                data = src.read_blob(h)
            except OSError as e:
                # a source-side read failure fails THIS blob's takers —
                # not the whole fan: the retry pass re-reads and re-ships
                # just the remainder. CrashInjected (the pusher process
                # itself dying) is a RuntimeError and still propagates.
                for i in targets:
                    fail(i, e)
                return
            with lock:
                fan.source_blob_reads += 1
                fan.blobs_broadcast += 1
            if pool is not None:
                recv_futures.extend(pool.submit(receive, i, h, data)
                                    for i in targets[1:])
                receive(targets[0], h, data)
            else:
                for i in targets:
                    receive(i, h, data)

        for off in range(0, len(hashes), _TRANSFER_BATCH):
            wave = hashes[off:off + _TRANSFER_BATCH]
            if pool is None or len(wave) <= 1:
                for h in wave:
                    ship(h)
            else:
                for f in [pool.submit(ship, h) for h in wave]:
                    f.result()
            # all ships joined, so no more receives get scheduled: drain
            for f in recv_futures:
                f.result()
            recv_futures.clear()

        # ---- per-replica finalize: descriptors (encoded ONCE for all
        # replicas), incremental verification, the manifest commit —
        # concurrent across replicas so a straggler only delays itself.
        encoded: Dict[str, bytes] = {}
        for i in range(len(receivers)):
            if not alive(i):
                continue
            for lid in missing_layers[i]:
                if lid not in encoded:
                    encoded[lid] = dumps(layers[lid].to_json()).encode()

        def finalize(i: int) -> None:
            recv = receivers[i]
            for lid in missing_layers[i]:
                recv.receive_layer(layers[lid], encoded=encoded[lid])
            stats = recv.commit(manifest, config)
            _stamp_dedup(stats, total_refs, total_payload, t0)
            fan.replicas[i].stats = stats
            if isinstance(recv, RelayNode):
                fan.replicas[i].children = recv.fan

        def safe_finalize(i: int) -> None:
            try:
                finalize(i)
            except Exception as e:  # noqa: BLE001
                fail(i, e)

        live = [i for i in range(len(receivers)) if alive(i)]
        if len(live) > 1 and pool is not None:
            for f in [pool.submit(safe_finalize, i) for i in live]:
                f.result()
        else:
            for i in live:
                safe_finalize(i)
    if retry is not None:
        # batch scopes restored first: each retry attempt opens its own,
        # so a retried replica's fsyncs are flushed by ITS commit
        _retry_failed(src, receivers, fan, name, tag, source, retry)
    fan.wall_s = time.perf_counter() - t0
    return fan


def push_delta(src: LayerStore, dst: LayerStore, name: str, tag: str,
               retry: Optional[RetryPolicy] = None) -> PushStats:
    """O(changed-bytes) push (module docstring): the single-destination
    form of ``replicate_fanout`` — one have-set negotiation, only missing
    layers + blobs over the pipelined transfer, incremental remote
    verification at commit. Failures re-raise instead of being isolated
    (after ``retry`` converges or quarantines, when one is given)."""
    fan = replicate_fanout(src, [dst], name, tag, retry=retry)
    rep = fan.replicas[0]
    if rep.exception is not None:
        raise rep.exception
    return rep.stats


def pull_delta(src: LayerStore, dst: LayerStore, name: str, tag: str,
               retry: Optional[RetryPolicy] = None) -> PushStats:
    """Pull = push with the roles swapped: ``dst`` negotiates its own
    have-set against ``src`` and receives only the delta."""
    return push_delta(src, dst, name, tag, retry=retry)


# --------------------------------------------------------------- offline
def export_delta(src: LayerStore, name: str, tag: str,
                 base_tag: Optional[str] = None,
                 base_images: Sequence[str] = ()) -> bytes:
    """Self-checking offline bundle of ``name:tag`` relative to
    ``name:base_tag`` (everything, when base_tag is None) — the
    ``docker save`` analogue of ``push_delta`` for air-gapped moves.

    ``base_images`` adds cross-image bases: layers and chunks reachable
    from those sibling images' newest committed tags (the receiver's
    TAG_WINDOW, per image) are treated as already-held and left out of
    the bundle, so a fine-tune exported against its base image carries
    only the adapter delta. The hints ride the header
    (``DeltaBundle.base_images``); a receiver that doesn't hold those
    images re-receives whatever its own cross-image holdings can't
    vouch for — a wrong hint costs a rejected import, never a silently
    wrong image (every blob is content-address-verified on receipt)."""
    manifest, config = src.read_image(name, tag)
    new_layers = [src.read_layer(lid) for lid in manifest.layer_ids]
    base_layers: List[LayerDescriptor] = []
    if base_tag is not None:
        base_manifest, _ = src.read_image(name, base_tag)
        base_layers = [src.read_layer(lid)
                       for lid in base_manifest.layer_ids]
    for img in base_images:
        for i, t in enumerate(sorted(src.list_tags(img), reverse=True)):
            if i >= DeltaReceiver.TAG_WINDOW:
                break
            try:
                m, _ = src.read_image(img, t)
            except (OSError, ValueError, KeyError):
                continue
            base_layers.extend(src.read_layer(lid) for lid in m.layer_ids
                               if src.has_layer(lid))
    missing, rekey, chunks = diff_manifests(base_layers, new_layers)
    return encode_delta(DeltaBundle(
        name=name, tag=tag, base_tag=base_tag or "",
        manifest=manifest, config=config, layers=missing, rekey=rekey,
        blobs={h: src.read_blob(h) for h in sorted(chunks)},
        base_images=list(base_images)))


def import_delta(dst, data: bytes) -> PushStats:
    """Apply an offline bundle through the same receive + incremental
    verification path a live push uses (decode already content-address-
    verified every payload; the receiver re-verifies on receipt anyway —
    defense in depth, still only the new bytes).

    ``dst`` may be a LayerStore/path or a ``RelayNode`` — the offline form
    of the relay topology: the bundle's header (``DeltaBundle.layer_meta``
    + blob index) seeds the child negotiation exactly like a live parent's
    delta header would, so one sneaker-netted bundle re-fans to a whole
    edge tier with the usual one-read/one-forward accounting."""
    bundle = decode_delta(data)
    receiver = _as_receiver(dst)
    receiver.begin_push()                  # re-arm a reused receiver
    with contextlib.ExitStack() as stack:
        for s in (receiver.all_stores() if isinstance(receiver, RelayNode)
                  else (receiver.store,)):
            stack.enter_context(_BatchScope(s))
        if isinstance(receiver, RelayNode):
            # the negotiated path: scan committed holdings AND seed every
            # child with the bundle header's layer metadata

            def held(lid):
                # a descriptor orphaned (possibly torn) by a crashed push
                # must degrade to "unknown family", not crash the import
                try:
                    return receiver.store.read_layer(lid) \
                        if receiver.store.has_layer(lid) else None
                except (OSError, ValueError, KeyError):
                    return None

            meta = bundle.layer_meta(held=held)
            receiver.negotiate(bundle.name, meta)
            receiver.rekey = dict(bundle.rekey)
            # probe the bundle's payload UNION the carried layers' full
            # chunk lists: a child staler than the bundle's base may lack
            # chunks the bundle doesn't carry but the relay already holds
            # committed — exactly what a live parent's probe list covers
            probe = set(bundle.blobs)
            for layer in bundle.layers:
                for rec in layer.records:
                    probe.update(rec.chunks)
            receiver.probe_blobs(sorted(probe))
        else:
            # index committed holdings up front so receive_layer's
            # immutability gate and commit's twin checks apply exactly as
            # on the live path
            receiver._scan_committed(bundle.name)
            receiver.rekey = dict(bundle.rekey)
        for h in sorted(bundle.blobs):
            receiver.receive_blob(h, bundle.blobs[h])
        for layer in bundle.layers:
            receiver.receive_layer(layer)
        stats = receiver.commit(bundle.manifest, bundle.config)
    return stats


# -------------------------------------------------------------- squashing
#: squash_deltas holds both endpoint tags against retention while it reads
SQUASH_LEASE_TTL_S = 600.0


def squash_deltas(store: LayerStore, name: str, from_tag: str,
                  to_tag: str) -> DeltaBundle:
    """Merge the per-commit delta records between ``from_tag`` and
    ``to_tag`` into ONE static bundle — the OSTree static-delta move: a
    lagging edge pays one merged delta instead of k per-commit hops or
    the full-pull fall-through.

    The composition reads the delta records ``inject_image_multi``
    already writes into the config history (``history_delta_chain``) and
    chains the layer-identity maps end-to-end
    (``compose_delta_records``): a layer injected once and re-keyed k-1
    times squashes to one re-key-verified clone; a layer rewritten at
    every hop ships once, with its final bytes. The chunk payload is
    derived from the STORE (final carried layers' chunks minus
    everything reachable at ``from_tag``), never from the capped
    per-record chunk lists — so intermediate rewrites of the same chunk
    collapse to the final bytes by construction, and a truncated
    history record can't truncate the bundle. When the history chain is
    unrecoverable (``from_tag`` fell off the 64-entry cap, a full
    rebuild sits in the span) or a composed re-key disagrees with the
    config locks, it falls back to a store-level re-diff
    (``diff_manifests``) — same bundle, derived the expensive way.

    Both endpoint tags are leased for the duration so a concurrent
    ``prune_steps``/``gc`` can't sweep them mid-read. The result applies
    through the ordinary ``import_delta`` path and is bit-identity
    checkable with ``verify_squashed_bundle``."""
    owner = f"squash/{new_uuid()}"
    store.acquire_lease(name, from_tag, owner, SQUASH_LEASE_TTL_S)
    store.acquire_lease(name, to_tag, owner, SQUASH_LEASE_TTL_S)
    try:
        to_manifest, to_config = store.read_image(name, to_tag)
        from_manifest, from_config = store.read_image(name, from_tag)
        chain = history_delta_chain(to_config, name, from_tag)
        rekey: Dict[str, str] = {}
        carried: List[str] = []
        if chain is not None:
            origin = compose_delta_records(chain)
            from_ids = set(from_manifest.layer_ids)
            for lid in to_manifest.layer_ids:
                base_lid, changed = origin.get(lid, (lid, False))
                if lid not in origin:
                    if lid not in from_ids:
                        chain = None    # unexplained new layer: re-diff
                        break
                    continue            # untouched, id shared verbatim
                if changed or base_lid not in from_ids:
                    carried.append(lid)
                elif from_config.layer_checksums.get(base_lid) != \
                        to_config.layer_checksums.get(lid):
                    chain = None        # history contradicts the locks
                    break
                else:
                    rekey[lid] = base_lid
        if chain is None:
            base_layers = [store.read_layer(lid)
                           for lid in from_manifest.layer_ids]
            new_layers = [store.read_layer(lid)
                          for lid in to_manifest.layer_ids]
            missing, rekey, chunks = diff_manifests(base_layers, new_layers)
        else:
            # the bundle ships every layer whose ID the base lacks — a
            # re-keyed clone's descriptor still crosses (fresh id + chain
            # checksums), it just carries no chunk payload
            changed = set(carried)
            missing = [store.read_layer(lid) for lid in to_manifest.layer_ids
                       if lid in changed or lid in rekey]
            base_chunks: Set[str] = set()
            for lid in from_manifest.layer_ids:
                for rec in store.read_layer(lid).records:
                    base_chunks.update(rec.chunks)
            chunks = {h for layer in missing if layer.layer_id in changed
                      for rec in layer.records
                      for h in rec.chunks} - base_chunks
        return DeltaBundle(
            name=name, tag=to_tag, base_tag=from_tag,
            manifest=to_manifest, config=to_config, layers=missing,
            rekey=dict(rekey),
            blobs={h: store.read_blob(h) for h in sorted(chunks)})
    finally:
        store.release_lease(name, owner)


def verify_squashed_bundle(src: LayerStore, bundle: DeltaBundle) -> List[str]:
    """Bit-identity proof for a squashed bundle: seed a scratch store
    with a full export of the bundle's base tag, apply the bundle
    through the normal ``import_delta`` path, then ``verify_image(
    deep=True)`` AND byte-compare every reachable chunk against ``src``.
    Returns the problem list (empty = proven identical)."""
    import shutil
    import tempfile

    tmp = tempfile.mkdtemp(prefix="squash-verify-")
    try:
        scratch = LayerStore(tmp, chunk_bytes=src.chunk_bytes)
        if bundle.base_tag:
            import_delta(scratch, export_delta(src, bundle.name,
                                               bundle.base_tag))
        import_delta(scratch, encode_delta(bundle))
        problems = scratch.verify_image(bundle.name, bundle.tag, deep=True)
        manifest, _ = src.read_image(bundle.name, bundle.tag)
        if manifest.layer_ids != scratch.read_image(
                bundle.name, bundle.tag)[0].layer_ids:
            problems.append("manifest layer order diverged")
        for lid in manifest.layer_ids:
            for rec in src.read_layer(lid).records:
                for h in rec.chunks:
                    if scratch.read_blob(h) != src.read_blob(h):
                        problems.append(f"chunk {h[:12]} bytes diverged")
        return problems
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


class PassiveRegistry:
    """Static bundles + a signed index, published as plain files any dumb
    HTTP / object store can serve — no smart endpoint, no per-follower
    state, ZERO negotiation round-trips on the pull path.

    Layout under ``root`` (a directory, or a read-only ``http(s)://``
    base URL)::

        <root>/<image>/index.json                       signed BundleIndex
        <root>/<image>/bundles/<from>__<to>.rdb         encoded DeltaBundle
        <root>/<image>/bundles/full__<to>.rdb           full bundle

    Publishing writes bundle files FIRST and renames the index into
    place LAST, so a crash mid-publish leaves a stale-but-consistent
    index: readers either see the old advertisement or the complete new
    one, never a reference to a half-written bundle. Fetches verify the
    advertised size + sha256 before decoding (and ``decode_delta``
    re-verifies every payload) — a truncated or bit-rotted bundle is
    detected at the edge and merely skipped by the chain planner.

    Fault points (ft/faults.py): ``bundle.publish`` fires on every file
    the publisher writes, ``bundle.fetch`` on every file a reader pulls
    (keys ``<root>:<image>:<from>-><to>`` and ``<root>:<image>:index``)."""

    INDEX_NAME = "index.json"

    def __init__(self, root: str, key: bytes = b""):
        self.root = str(root)
        self.key = key
        self._http = self.root.startswith(("http://", "https://"))

    # ------------------------------------------------------------ layout
    def _join(self, *parts: str) -> str:
        if self._http:
            return "/".join([self.root.rstrip("/"), *parts])
        return os.path.join(self.root, *parts)

    @staticmethod
    def bundle_relpath(from_tag: str, to_tag: str) -> str:
        return f"bundles/{from_tag or 'full'}__{to_tag}.rdb"

    # ------------------------------------------------------------ reading
    def _read(self, *parts: str) -> bytes:
        if self._http:
            import urllib.request
            with urllib.request.urlopen(self._join(*parts)) as resp:
                return resp.read()
        with open(self._join(*parts), "rb") as f:
            return f.read()

    def read_index(self, name: str) -> BundleIndex:
        """Fetch + signature-verify the image's index. Raises OSError /
        ``DeltaFormatError`` — callers treat either as "no usable
        index", never as a fatal poll error."""
        raw = fault_point("bundle.fetch", key=f"{self.root}:{name}:index",
                          data=self._read(name, self.INDEX_NAME))
        return decode_index(raw, key=self.key)

    def fetch_bundle(self, name: str, entry: BundleEntry) -> bytes:
        """Fetch one advertised bundle and verify it against the index's
        size + content address BEFORE handing it to ``decode_delta`` —
        truncation, bit-rot and a publish that crashed mid-write all
        surface here as ``DeltaFormatError``."""
        key = f"{self.root}:{name}:{entry.from_tag or 'full'}->{entry.to_tag}"
        raw = fault_point("bundle.fetch", key=key,
                          data=self._read(name, *entry.path.split("/")))
        if len(raw) != entry.size or sha256_hex(raw) != entry.sha256:
            raise DeltaFormatError(
                f"bundle {entry.path} does not match its advertisement")
        return raw

    # --------------------------------------------------------- publishing
    def _write(self, relparts: Sequence[str], data: bytes) -> None:
        if self._http:
            raise ValueError("http registry roots are read-only")
        path = os.path.join(self.root, *relparts)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())    # bytes durable BEFORE the rename —
            # a post-crash index must never advertise a torn bundle
        os.replace(tmp, path)       # readers see old bytes or new, never torn

    def publish_bundle(self, store: LayerStore, name: str, to_tag: str,
                       from_tag: str = "") -> BundleEntry:
        """Encode + write one bundle file (squashed when ``from_tag`` is
        given, full otherwise) and return its index entry. The entry
        advertises the hash of the INTENDED bytes, computed before the
        ``bundle.publish`` fault point — a corrupted write lands on disk
        but can never pass a reader's verification."""
        if from_tag:
            data = encode_delta(squash_deltas(store, name, from_tag, to_tag))
        else:
            data = export_delta(store, name, to_tag)
        entry = BundleEntry(from_tag=from_tag, to_tag=to_tag,
                            path=self.bundle_relpath(from_tag, to_tag),
                            size=len(data), sha256=sha256_hex(data))
        key = f"{self.root}:{name}:{from_tag or 'full'}->{to_tag}"
        self._write([name, *entry.path.split("/")],
                    fault_point("bundle.publish", key=key, data=data))
        return entry

    def publish_image(self, store: LayerStore, name: str, head_tag: str,
                      from_tags: Sequence[str] = (), full: bool = True
                      ) -> BundleIndex:
        """Publish ``head_tag`` as a full bundle plus one squashed bundle
        per ``from_tags`` entry, then atomically advance the signed
        index. Existing entries whose endpoint tags are still committed
        in ``store`` are carried forward (the per-commit chain stays
        advertised); entries referencing pruned tags or missing files
        are dropped — the retention-awareness half of the contract. A
        single bundle that fails to publish (a fault, a mid-squash
        prune) is skipped and simply not advertised; the index written
        at the end only ever names bundles that landed."""
        prior = []
        generation = 0
        try:
            old = decode_index(self._read(name, self.INDEX_NAME),
                               key=self.key)
            generation = old.generation
            prior = old.entries
        except (OSError, ValueError):
            pass
        entries: List[BundleEntry] = []
        for e in prior:
            if (e.from_tag, e.to_tag) == ("", head_tag) or \
                    (e.from_tag and e.from_tag in from_tags and
                     e.to_tag == head_tag):
                continue            # about to be republished
            if e.from_tag and not store.has_image(name, e.from_tag):
                continue            # base pruned at the source
            if not store.has_image(name, e.to_tag):
                continue            # target pruned at the source
            if not self._http and not os.path.exists(
                    self._join(name, *e.path.split("/"))):
                continue            # bundle file vanished
            entries.append(e)
        wanted = [(f, head_tag) for f in from_tags if f]
        if full:
            wanted.append(("", head_tag))
        for from_tag, to_tag in wanted:
            try:
                entries.append(self.publish_bundle(store, name, to_tag,
                                                   from_tag=from_tag))
            except CrashInjected:
                raise               # simulated publisher death
            except (ConnectionError, OSError, ValueError, KeyError):
                continue            # not advertised; index stays honest
        index = BundleIndex(image=name, head=head_tag,
                            generation=generation + 1, entries=entries)
        try:
            data = fault_point("bundle.publish",
                               key=f"{self.root}:{name}:index",
                               data=encode_index(index, key=self.key))
            self._write([name, self.INDEX_NAME], data)
        except CrashInjected:
            raise               # simulated publisher death
        except (ConnectionError, OSError):
            pass                # stale-but-consistent: readers keep the
                                # old advertisement; the next publish
                                # (or a restarted one) advances it
        return index

    def prune(self, store: LayerStore, name: str) -> int:
        """Drop index entries (and their bundle files) whose endpoint
        tags are no longer committed in ``store`` — the publisher-side
        retention sweep. Returns the number of entries dropped; safe to
        call from a ``LayerStore`` gc hook (see ``attach_gc``)."""
        try:
            index = decode_index(self._read(name, self.INDEX_NAME),
                                 key=self.key)
        except (OSError, ValueError):
            return 0
        keep, dropped = [], []
        for e in index.entries:
            alive = store.has_image(name, e.to_tag) and \
                (not e.from_tag or store.has_image(name, e.from_tag))
            (keep if alive else dropped).append(e)
        if not dropped:
            return 0
        index.entries = keep
        index.generation += 1
        if index.head and not store.has_image(name, index.head):
            index.head = max((e.to_tag for e in keep), default="")
        self._write([name, self.INDEX_NAME],
                    encode_index(index, key=self.key))
        for e in dropped:
            try:
                os.remove(self._join(name, *e.path.split("/")))
            except OSError:
                pass
        return len(dropped)

    def attach_gc(self, store: LayerStore, name: str) -> None:
        """Register the retention sweep as a ``store.gc()`` hook: every
        garbage collection also drops published bundles whose endpoint
        tags it swept (reported as ``bundles_pruned`` in the gc stats)."""
        store.add_gc_hook(
            lambda st: {"bundles_pruned": self.prune(st, name)})


# ---------------------------------------------------------------- repair
#: a RepairSession holds its image's tags against retention while it runs
REPAIR_LEASE_TTL_S = 600.0


class RepairFailed(RuntimeError):
    """Anti-entropy repair could not fully restore the image: at least one
    damaged blob or layer descriptor had no intact source among the given
    peers. Everything sourceable WAS repaired and flushed before this was
    raised; the rest stays quarantined (the image is visibly-incomplete,
    never silently-corrupt). The partial accounting rides on ``.report``;
    ``repair_image(..., force=True)`` returns that report instead of
    raising — the ``remove_image(force=)``-style explicit override for
    operators who want the partial heal plus the unsourced list."""

    def __init__(self, msg: str, report: "RepairReport"):
        super().__init__(msg)
        self.report = report


@dataclass
class RepairReport:
    """Wire-accounted outcome of one anti-entropy repair.

    ``bytes_pulled`` counts EVERY byte fetched from peers (including
    copies that failed re-verification and were discarded);
    ``damaged_bytes`` counts the bytes actually swapped in (good blob
    payloads + refetched descriptor encodings). Their ratio —
    ``wire_amplification`` — is the anti-entropy efficiency claim: repair
    pulls only the damaged bytes, so with healthy peers it sits at 1.0
    (the CI gate allows <= 1.25x for retried/rotten peer copies).
    ``quarantined`` lists blobs moved aside (bad bytes preserved for
    forensics); ``unsourced`` lists what no peer could supply.
    """

    name: str = ""
    tag: str = ""
    planned_blobs: int = 0        # blobs the plan found damaged/missing
    planned_layers: int = 0       # descriptors the plan found damaged
    repaired_blobs: int = 0
    repaired_layers: int = 0
    bytes_pulled: int = 0         # every peer byte fetched (incl. discards)
    damaged_bytes: int = 0        # bytes actually swapped in
    quarantined: List[str] = field(default_factory=list)
    unsourced: List[str] = field(default_factory=list)
    peer_used: Dict[str, str] = field(default_factory=dict)
    verified_clean: bool = False  # final verify_image(deep=True) ran clean
    wall_s: float = 0.0

    @property
    def wire_amplification(self) -> float:
        """bytes_pulled / damaged_bytes (1.0 = perfectly targeted pull)."""
        return self.bytes_pulled / max(self.damaged_bytes, 1)


class _StorePeer:
    """Repair-source adapter over anything holding a live ``LayerStore``:
    the store itself, a root path, or a ``DeltaReceiver``/``RelayNode``
    (anything with a ``.store``). Fetches never raise — a peer whose own
    copy is missing or unreadable simply returns None and the session
    tries the next peer."""

    def __init__(self, store: LayerStore, label: str = ""):
        self.store = store
        self.label = label or store.root

    def fetch_blob(self, h: str) -> Optional[bytes]:
        if not self.store.has_blob(h):
            return None
        try:
            return self.store.read_blob(h)
        except OSError:
            return None

    def fetch_layer(self, lid: str
                    ) -> Optional[Tuple[LayerDescriptor, bytes]]:
        if not self.store.has_layer(lid):
            return None
        try:
            layer = self.store.read_layer(lid, use_cache=False)
        except (OSError, ValueError, KeyError):
            return None
        return layer, dumps(layer.to_json()).encode()


class _BundlePeer:
    """Repair-source adapter over an offline ``DeltaBundle`` (or raw RDB1
    bytes) — the air-gapped case: a node with no live peer heals from the
    same bundle artifact that built the image."""

    def __init__(self, bundle: DeltaBundle, label: str = "bundle"):
        self.bundle = bundle
        self.label = label
        self._layers = {ly.layer_id: ly for ly in bundle.layers}

    def fetch_blob(self, h: str) -> Optional[bytes]:
        return self.bundle.blobs.get(h)

    def fetch_layer(self, lid: str
                    ) -> Optional[Tuple[LayerDescriptor, bytes]]:
        layer = self._layers.get(lid)
        if layer is None:
            return None
        return layer, dumps(layer.to_json()).encode()


def _as_peer(p):
    """Normalize any DeltaReceiver-shaped repair source to a peer adapter:
    LayerStore | root path | DeltaReceiver/RelayNode (``.store``) |
    DeltaBundle | encoded RDB1 bytes | an adapter passed through."""
    if isinstance(p, (_StorePeer, _BundlePeer)):
        return p
    if isinstance(p, DeltaBundle):
        return _BundlePeer(p)
    if isinstance(p, (bytes, bytearray)):
        return _BundlePeer(decode_delta(bytes(p)))
    if isinstance(p, LayerStore):
        return _StorePeer(p)
    if isinstance(p, str):
        return _StorePeer(LayerStore(p))
    store = getattr(p, "store", None)
    if isinstance(store, LayerStore):
        return _StorePeer(store, label=getattr(p, "name", "") or store.root)
    raise TypeError(f"cannot use {type(p).__name__} as a repair peer")


class RepairSession:
    """Anti-entropy repair of one committed image — the healing half of
    the scrub/repair loop (delta machinery in reverse: instead of pushing
    the bytes a peer lacks, pull exactly the bytes THIS store lost).

    ``plan()`` walks the image against its own config locks and finds the
    damaged set: layer descriptors whose content checksum or config lock
    no longer match, and blobs that are missing or fail re-hash (a
    ``ScrubReport`` narrows the re-hash to its listed candidates; without
    one the plan deep-walks the whole image). The plan takes a retention
    lease on the tag and pins every reachable blob/layer path against
    ``gc()`` — a half-repaired image must never be swept under the
    session (a corrupt descriptor under-marks, so without the pin gc
    would collect the good siblings of the damaged layer).

    ``run()`` then, under one batch-durability scope: (1) refetches
    damaged descriptors from the peers, accepting only copies that match
    the local config's checksum/chain locks, and deep-checks their chunk
    set; (2) quarantines every corrupt on-disk blob up front — from this
    point the store is visibly-incomplete, never silently-corrupt, which
    is exactly the SIGKILL invariant (a killed session leaves quarantined
    blobs plus possibly some already-verified replacements, both states a
    clean retry converges from); (3) pulls only the damaged blobs,
    re-verifying each against its content address on receipt (a peer
    whose copy is ALSO rotten is skipped — any-peer repair); (4) flushes
    via the scope's ``sync_for_commit`` and deep-verifies the image.
    Blobs no peer could source are reported ``unsourced`` and the session
    raises ``RepairFailed`` unless ``force=True``.
    """

    def __init__(self, store: LayerStore, name: str, tag: str, peers,
                 scrub_report=None):
        self.store = store
        self.name = name
        self.tag = tag
        self.peers = [_as_peer(p) for p in peers]
        self.scrub_report = scrub_report
        self.owner = f"repair/{new_uuid()}"
        self.report = RepairReport(name=name, tag=tag)
        self.manifest: Optional[Manifest] = None
        self.config: Optional[ImageConfig] = None
        self.damaged_blobs: List[str] = []
        self.damaged_layers: List[str] = []
        self._protected: set = set()
        self._planned = False

    # ------------------------------------------------------------- planning
    def _layer_ok(self, lid: str) -> Tuple[bool, Optional[LayerDescriptor]]:
        st = self.store
        if not st.has_layer(lid):
            return False, None
        try:
            layer = st.read_layer(lid, use_cache=False)
        except (OSError, ValueError, KeyError):
            return False, None
        ok = (layer.layer_id == lid
              and content_checksum(layer.records) == layer.checksum
              and self.config.layer_checksums.get(lid) == layer.checksum
              and self.config.layer_chains.get(lid) == layer.chain)
        return ok, layer if ok else None

    def plan(self) -> "RepairSession":
        """Find the damaged set, lease the tag, pin the image's reach."""
        st = self.store
        try:
            self.manifest, self.config = st.read_image(self.name, self.tag)
        except (OSError, ValueError, KeyError) as e:
            raise RepairFailed(
                f"{self.name}:{self.tag} manifest/config unreadable — "
                f"nothing to anchor a repair to ({e})", self.report)
        st.acquire_lease(self.name, self.tag, self.owner,
                         REPAIR_LEASE_TTL_S)
        listed = None
        if self.scrub_report is not None:
            listed = set(self.scrub_report.corrupt_blob_hashes)
        damaged_blobs: set = set()
        damaged_layers: List[str] = []
        protect: set = set()
        for lid in self.manifest.layer_ids:
            protect.add(st._layer_path(lid))
            ok, layer = self._layer_ok(lid)
            if not ok:
                damaged_layers.append(lid)
                continue
            for rec in layer.records:
                for h in rec.chunks:
                    protect.add(st._blob_path(h))
                    if not st.has_blob(h):
                        damaged_blobs.add(h)
                    elif (listed is None or h in listed) and \
                            sha256_hex(st.read_blob(h)) != h:
                        damaged_blobs.add(h)
        if damaged_layers:
            # an unreadable descriptor hides its chunk list, so the
            # damaged layer's reach cannot be enumerated — and gc's mark
            # phase is blinded the same way. Pin every on-disk blob until
            # the descriptor is refetched (run() narrows the pin to the
            # real chunk set as soon as it has one); without this, a
            # concurrent gc would sweep the damaged layer's GOOD blobs
            # out from under the session.
            blob_root = os.path.join(st.root, "blobs", "sha256")
            if os.path.isdir(blob_root):
                for sub in sorted(os.listdir(blob_root)):
                    d = os.path.join(blob_root, sub)
                    if os.path.isdir(d):
                        protect.update(os.path.join(d, fn)
                                       for fn in os.listdir(d))
        st.protect_paths(protect)
        self._protected = set(protect)
        self.damaged_blobs = sorted(damaged_blobs)
        self.damaged_layers = damaged_layers
        self.report.planned_blobs = len(self.damaged_blobs)
        self.report.planned_layers = len(self.damaged_layers)
        self._planned = True
        return self

    # ------------------------------------------------------------ execution
    def _refetch_layers(self, pending: set) -> None:
        """Refetch damaged descriptors, validated against the LOCAL config
        locks (the config is the trust anchor — a peer cannot swap in a
        descriptor our committed config never vouched for), then extend
        ``pending`` with any of their chunks that are missing or rotten
        here."""
        st, rep = self.store, self.report
        for lid in self.damaged_layers:
            fetched = False
            for peer in self.peers:
                got = peer.fetch_layer(lid)
                if got is None:
                    continue
                layer, enc = got
                rep.bytes_pulled += len(enc)
                if (layer.layer_id != lid
                        or content_checksum(layer.records) != layer.checksum
                        or self.config.layer_checksums.get(lid)
                        != layer.checksum
                        or self.config.layer_chains.get(lid) != layer.chain):
                    continue        # peer's copy diverges from our locks
                chunk_paths = {st._blob_path(h)
                               for r in layer.records for h in r.chunks}
                st.protect_paths(chunk_paths)
                self._protected |= chunk_paths
                st.write_layer(layer, encoded=enc)
                rep.damaged_bytes += len(enc)
                rep.repaired_layers += 1
                rep.peer_used[lid] = peer.label
                for r in layer.records:
                    for h in r.chunks:
                        if not st.has_blob(h):
                            pending.add(h)
                        elif sha256_hex(st.read_blob(h)) != h:
                            pending.add(h)
                fetched = True
                break
            if not fetched:
                rep.unsourced.append(f"layer:{lid}")

    def run(self, force: bool = False) -> RepairReport:
        """Execute the repair (planning first if needed). Returns the
        report; raises ``RepairFailed`` when anything stayed unsourced and
        ``force`` is False. Lease and gc pins are always released."""
        t0 = time.perf_counter()
        st, rep = self.store, self.report
        try:
            if not self._planned:
                self.plan()
            with _BatchScope(st):
                pending = set(self.damaged_blobs)
                self._refetch_layers(pending)
                # quarantine first: every pending blob still on disk is a
                # failed re-hash — move the bad bytes out of the namespace
                # BEFORE pulling (write_blob dedups on existence, and a
                # SIGKILL here must leave visibly-incomplete, not
                # silently-corrupt)
                for h in sorted(pending):
                    if st.has_blob(h) and st.quarantine_blob(h):
                        rep.quarantined.append(h)
                for h in sorted(pending):
                    data = None
                    src_label = ""
                    for peer in self.peers:
                        raw = peer.fetch_blob(h)
                        if raw is None:
                            continue
                        raw = fault_point("repair.pull",
                                          f"{st.root}:{h}", raw)
                        rep.bytes_pulled += len(raw)
                        if sha256_hex(raw) != h:
                            continue    # peer's copy is ALSO rotten
                        data, src_label = raw, peer.label
                        break
                    if data is None:
                        rep.unsourced.append(h)
                        continue
                    st.write_blob(h, data)
                    rep.damaged_bytes += len(data)
                    rep.repaired_blobs += 1
                    rep.peer_used[h] = src_label
                # crash window probe: quarantines + swap-ins happened,
                # the durability flush has not (SIGKILL tests kill here)
                fault_point("repair.commit", st.root)
            if not rep.unsourced:
                rep.verified_clean = \
                    st.verify_image(self.name, self.tag, deep=True) == []
            rep.wall_s = time.perf_counter() - t0
            if rep.unsourced and not force:
                raise RepairFailed(
                    f"{self.name}:{self.tag}: {len(rep.unsourced)} "
                    f"item(s) unsourceable from {len(self.peers)} peer(s) "
                    f"(quarantined, image left visibly-incomplete): "
                    f"{rep.unsourced[:4]}", rep)
            return rep
        finally:
            st.unprotect_paths(self._protected)
            st.release_lease(self.name, self.owner, self.tag)


def repair_image(store: LayerStore, name: str, tag: str, peers,
                 scrub_report=None, force: bool = False) -> RepairReport:
    """Heal ``name:tag`` in ``store`` from any peer holding good copies —
    see ``RepairSession``. ``peers`` accepts any mix of live stores, root
    paths, ``DeltaReceiver``/``RelayNode`` fronts, ``DeltaBundle``s or
    encoded bundle bytes; they are tried in order per damaged item.
    ``scrub_report`` narrows the damage plan to the scrub's findings;
    ``force=True`` returns a partial report instead of raising when some
    items have no intact source anywhere."""
    return RepairSession(store, name, tag, peers,
                         scrub_report=scrub_report).run(force=force)
