"""Remote-registry model — paper §III.C (redeployment).

A "remote" is another LayerStore behind a ``DeltaReceiver`` — the endpoint
of the wire protocol, which *verifies everything it receives*. Two push
paths share the same integrity gate (a naive in-place mutation — same layer
id, diverged checksum — is REJECTED; a clone-before-inject with a new id
and re-keyed manifest is ACCEPTED):

* ``push`` — the seed O(image) baseline: walk every layer, send missing
  blobs one at a time, then ``verify_image(deep=True)`` at the destination
  (a full re-hash of the whole image on every push).

* ``push_delta`` — the O(changed-bytes) path. The have-set is negotiated
  in **batched set-difference exchanges** (``DeltaReceiver.negotiate``:
  every has_layer probe in one O(#layers) request; ``probe_blobs``: every
  has_blob probe in one request covering only new-content layers' chunks),
  telling the source exactly what the remote is missing *and* which missing
  layers are content-identical re-keyed clones of layers the remote already
  verified (matched by family + content checksum — the re-key table). Only
  genuinely new chunk blobs cross the wire, on a **pipelined transfer**: blob read -> send ->
  content-address verify -> write run concurrently per blob on the shared
  hash pool, with the receiving store under ``durability="batch"`` so every
  per-blob fsync coalesces into one concurrent flush at the remote
  manifest commit. Verification is **incremental**: received blobs are
  hashed exactly once (on receipt, overlapped with the transfer), re-keyed
  clones are checked by checksum equality against the layer the remote
  already holds, and only layers with genuinely new content get the deep
  membership check — the remote never re-hashes bytes it verified on an
  earlier push. ``PushStats.layers_deep_verified`` proves the "deep-verify
  only new layers" claim; CI gates it.

* ``replicate_fanout`` — the fleet form of ``push_delta``: one training
  source feeding N serving replicas. The have-set is negotiated in ONE
  round (every replica answers the same O(#layers) request; the answers
  are unioned into a single plan), each changed blob is read from the
  source store exactly once and broadcast to every replica missing it,
  and failures are isolated per replica (``ReplicaResult``) so a sick or
  slow destination never blocks the healthy ones — a clean retry
  converges it. ``push_delta`` itself is the N=1 special case.

``export_delta``/``import_delta`` are the offline (``docker save``-style)
form of the same protocol: a self-checking ``DeltaBundle`` byte string
computed against a base tag instead of a live have-set.
"""
from __future__ import annotations

import contextlib
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .chunker import hash_pool, sha256_hex
from .delta import DeltaBundle, decode_delta, encode_delta
from .diff import diff_manifests
from .manifest import (ImageConfig, LayerDescriptor, Manifest, chain_checksum,
                       content_checksum, dumps)
from .store import LayerStore


class PushRejected(RuntimeError):
    pass


@dataclass
class PushStats:
    blobs_sent: int = 0
    blobs_dedup: int = 0
    layers_sent: int = 0
    layers_dedup: int = 0
    # bytes_sent is EVERYTHING on the wire: blob payloads + layer
    # descriptors + manifest/config (+ the negotiation exchange for the
    # delta path) — true wire amplification, not just payload.
    bytes_sent: int = 0
    bytes_payload: int = 0       # blob payload bytes only
    bytes_meta: int = 0          # descriptor + manifest/config (+ have-set)
    bytes_deduped: int = 0       # payload bytes NOT resent thanks to dedup
    wall_s: float = 0.0
    # Incremental-verification accounting (delta path; seed push re-hashes
    # the whole image so its deep count is every layer).
    layers_deep_verified: int = 0
    layers_rekey_verified: int = 0
    blobs_hashed_remote: int = 0


def push(src: LayerStore, dst: LayerStore, name: str, tag: str) -> PushStats:
    """Seed baseline: O(image) walk + full deep re-verification at dst."""
    stats = PushStats()
    t0 = time.perf_counter()
    problems = src.verify_image(name, tag, deep=False)
    if problems:
        raise PushRejected(f"source image fails verification: {problems}")
    manifest, config = src.read_image(name, tag)

    total_payload = 0
    for lid in manifest.layer_ids:
        layer = src.read_layer(lid)
        total_payload += layer.nbytes
        if dst.has_layer(lid):
            existing = dst.read_layer(lid)
            if existing.checksum != layer.checksum:
                # The paper's exact failure mode: same id, diverged content.
                raise PushRejected(
                    f"layer {lid}: remote holds a different checksum trace "
                    "for this id (in-place mutation without a new id?)")
            stats.layers_dedup += 1
        else:
            stats.layers_sent += 1
        for rec in layer.records:
            for h in rec.chunks:
                if dst.has_blob(h):
                    stats.blobs_dedup += 1
                else:
                    data = src.read_blob(h)
                    dst.write_blob(h, data)
                    stats.blobs_sent += 1
                    stats.bytes_payload += len(data)
        # the seed path resends EVERY descriptor, dedup'd or not
        data = dumps(layer.to_json()).encode()
        stats.bytes_meta += len(data)
        dst.write_layer(layer, encoded=data)
    stats.bytes_meta += len(dumps(manifest.to_json()).encode())
    stats.bytes_meta += len(dumps(config.to_json()).encode())
    dst.write_image(manifest, config)

    problems = dst.verify_image(name, tag, deep=True)
    stats.layers_deep_verified = len(manifest.layer_ids)
    if problems:
        raise PushRejected(f"post-push verification failed: {problems}")
    stats.bytes_sent = stats.bytes_payload + stats.bytes_meta
    stats.bytes_deduped = total_payload - stats.bytes_payload
    stats.wall_s = time.perf_counter() - t0
    return stats


def pull(src: LayerStore, dst: LayerStore, name: str, tag: str) -> PushStats:
    return push(src, dst, name, tag)


# --------------------------------------------------------------------------
# Delta protocol
# --------------------------------------------------------------------------

@dataclass
class HaveSet:
    """The remote's answer to ONE negotiation request: what it is missing,
    plus the re-key table for missing layers it can prove content-identical
    to layers it already holds."""

    missing_layers: List[str] = field(default_factory=list)
    missing_blobs: Set[str] = field(default_factory=set)
    held_checksums: Dict[str, str] = field(default_factory=dict)
    rekey: Dict[str, str] = field(default_factory=dict)
    exchange_bytes: int = 0      # request+response size (counted as meta)


class _BatchScope:
    """Hold the receiving store in durability="batch" for the lifetime of a
    push so per-blob fsyncs coalesce at the remote manifest commit."""

    def __init__(self, store: LayerStore):
        self.store = store
        self._prev: Optional[str] = None

    def __enter__(self):
        self._prev = self.store.durability
        self.store.durability = "batch"
        return self

    def __exit__(self, *exc):
        # write_image (the commit) already flushed deferred fsyncs; on the
        # error path the dirty sets simply stay pending for the next commit.
        self.store.durability = self._prev
        return False


class DeltaReceiver:
    """The remote endpoint of a delta push.

    Wire ops: ``negotiate`` (one set-difference exchange), ``receive_layer``
    / ``receive_blob`` (streamed; blobs are content-address-verified on
    receipt — the only time new bytes are ever hashed), and ``commit``
    (incremental verification + the manifest rename). A crash anywhere
    before ``commit`` leaves the remote's previous tag fully intact: blobs
    and descriptors are orphans until the manifest rename, exactly the
    store's normal crash model.
    """

    # Tags scanned (newest first) when indexing the remote's holdings: the
    # re-key/family matches worth finding live in the most recent tags;
    # scanning fewer tags only costs extra deep verification, never
    # correctness — and keeps negotiate O(window), not O(push history).
    TAG_WINDOW = 8

    def __init__(self, store: LayerStore):
        self.store = store
        self.negotiations = 0        # negotiate() exchanges this push
        self._verified_blobs: Set[str] = set()
        self._received_layers: Dict[str, LayerDescriptor] = {}
        # chunk ids referenced by COMMITTED layers of this image (built by
        # _scan_committed, pure metadata): membership here means present
        # AND verified by an earlier successful push — no stat, no hash
        self._known_chunks: Set[str] = set()
        # layer ids reachable from a committed manifest. A descriptor file
        # that exists but is NOT in this set is an orphan of a crashed push
        # — possibly torn under batch durability — and must never be
        # trusted as "held".
        self._committed_layers: Optional[Set[str]] = None
        self.rekey: Dict[str, str] = {}
        self.stats = PushStats()
        self._stats_lock = threading.Lock()   # receive_blob runs on a pool

    def _scan_committed(self, name: str) -> Dict[Tuple[str, str], str]:
        """Index this store's committed holdings for ``name``.

        ``_committed_layers`` (the held/mutation-gate set) covers EVERY
        committed tag — an id referenced only by an old tag must still be
        protected from overwrite. Only the descriptor-reading work — the
        family index for re-key matching and ``_known_chunks`` — is bounded
        to the TAG_WINDOW newest tags; missing a match there only costs
        extra deep verification, never correctness."""
        by_family: Dict[Tuple[str, str], str] = {}
        self._committed_layers = set()
        for i, tag in enumerate(sorted(self.store.list_tags(name),
                                       reverse=True)):
            try:
                m, _ = self.store.read_image(name, tag)
            except (OSError, ValueError, KeyError):
                continue
            self._committed_layers.update(m.layer_ids)
            if i >= self.TAG_WINDOW:
                continue
            for lid in m.layer_ids:
                if not self.store.has_layer(lid):
                    continue
                layer = self.store.read_layer(lid)
                by_family.setdefault((layer.family, layer.checksum), lid)
                for rec in layer.records:
                    self._known_chunks.update(rec.chunks)
        return by_family

    # ------------------------------------------------------------ negotiate
    def negotiate(self, name: str,
                  layer_meta: Dict[str, Tuple[str, str]]) -> HaveSet:
        """The layer set-difference exchange — every has_layer probe
        batched into one request. ``layer_meta`` maps layer_id ->
        (family, content_checksum) for the manifest's layers, in manifest
        order (O(#layers) metadata, never chunk lists). Returns missing
        layers, checksums of held layers (the in-place-mutation gate runs
        against these), and the re-key table: missing layers whose
        (family, checksum) matches a layer this store already holds under
        the image's tags — those need no blob probes and no deep
        verification, because content-checksum equality over the chunk-hash
        list proves every blob is already present and verified.

        "Held" means reachable from a COMMITTED manifest — a descriptor
        orphaned by a crashed earlier push is reported missing, so it gets
        re-received and re-verified rather than trusted.
        """
        have = HaveSet()
        self.negotiations += 1
        by_family = self._scan_committed(name)

        for lid, (family, checksum) in layer_meta.items():
            if lid in self._committed_layers and self.store.has_layer(lid):
                have.held_checksums[lid] = self.store.read_layer(lid).checksum
                continue
            have.missing_layers.append(lid)
            twin = by_family.get((family, checksum))
            if twin is not None:
                have.rekey[lid] = twin
        # request = (lid, family, checksum) rows; response = the sets
        have.exchange_bytes = sum(
            len(lid) + len(fam) + len(cs)
            for lid, (fam, cs) in layer_meta.items())
        have.exchange_bytes += sum(
            len(lid) + len(cs) for lid, cs in have.held_checksums.items())
        have.exchange_bytes += sum(len(x) for x in have.missing_layers)
        have.exchange_bytes += sum(len(a) + len(b)
                                   for a, b in have.rekey.items())
        self.rekey = dict(have.rekey)
        return have

    def probe_blobs(self, chunk_ids: Sequence[str]) -> Set[str]:
        """The blob set-difference exchange — every has_blob probe batched
        into one request. Callers only probe chunks of genuinely-new-content
        layers (re-keyed clones were already settled by ``negotiate``), so
        this message is O(changed-layer chunks), not O(image chunks); and
        chunks already referenced by committed layers are answered from
        metadata (``_known_chunks``) without touching the filesystem.

        A blob that exists on disk but is NOT committed-known is an orphan
        of a crashed push — possibly torn (batch durability defers fsyncs).
        It is re-hashed here: intact orphans are adopted as verified; torn
        ones are deleted (unreferenced, so safe) and reported missing so
        the pusher resends them. Either way a retry after a crash
        converges; the cost is O(orphaned chunks), zero on a clean store."""
        missing: Set[str] = set()
        for h in chunk_ids:
            if h in self._known_chunks or h in self._verified_blobs:
                continue
            if not self.store.has_blob(h):
                missing.add(h)
                continue
            if sha256_hex(self.store.read_blob(h)) == h:
                self._verified_blobs.add(h)
                self.stats.blobs_hashed_remote += 1
            else:
                self.store.drop_blob(h)      # torn orphan: resend
                missing.add(h)
        self.stats.bytes_meta += sum(len(h) for h in chunk_ids)
        self.stats.bytes_meta += sum(len(h) for h in missing)
        return missing

    # ------------------------------------------------------------- receive
    def receive_layer(self, layer: LayerDescriptor,
                      encoded: Optional[bytes] = None) -> int:
        """A committed descriptor is IMMUTABLE at this store: receiving the
        same id with a diverged checksum is the in-place mutation the gate
        exists for (this is what keeps the offline ``import_delta`` path as
        safe as the negotiated one); an identical re-send is a no-op.
        ``encoded`` lets a fan-out source serialize each descriptor once
        for every replica (must be ``dumps(layer.to_json())``)."""
        if self._committed_layers is not None and \
                layer.layer_id in self._committed_layers and \
                self.store.has_layer(layer.layer_id):
            held = self.store.read_layer(layer.layer_id)
            if held.checksum != layer.checksum:
                raise PushRejected(
                    f"layer {layer.layer_id}: already committed here with a "
                    "different checksum trace (in-place mutation without a "
                    "new id?)")
            return 0
        data = encoded if encoded is not None \
            else dumps(layer.to_json()).encode()
        self._received_layers[layer.layer_id] = layer
        self.store.write_layer(layer, encoded=data)
        self.stats.layers_sent += 1
        self.stats.bytes_meta += len(data)
        return len(data)

    def receive_blob(self, h: str, data: bytes) -> int:
        """Content-address verification happens HERE, overlapped with the
        transfer — the only time a pushed byte is ever hashed remotely."""
        if sha256_hex(data) != h:
            raise PushRejected(f"blob {h[:12]}: payload does not match its "
                               "content address (corrupt transfer)")
        self.store.write_blob(h, data)
        with self._stats_lock:
            self._verified_blobs.add(h)
            self.stats.blobs_hashed_remote += 1
            self.stats.blobs_sent += 1
            self.stats.bytes_payload += len(data)
        return len(data)

    def _blob_ok(self, h: str) -> bool:
        """A chunk passes if it was verified on receipt this push, is
        referenced by a committed (earlier-verified) layer, or — the
        crashed-push orphan case — exists on disk AND re-hashes to its
        address (adopted into the verified set, counted once)."""
        if h in self._verified_blobs or h in self._known_chunks:
            return True
        if not self.store.has_blob(h):
            return False
        if sha256_hex(self.store.read_blob(h)) != h:
            return False
        self._verified_blobs.add(h)
        self.stats.blobs_hashed_remote += 1
        return True

    # -------------------------------------------------------------- commit
    def commit(self, manifest: Manifest, config: ImageConfig) -> PushStats:
        """Incremental verification, then the manifest rename.

        * committed pre-existing layer: checksum must equal the incoming
          config lock (same id + diverged checksum = the paper's in-place
          mutation — rejected). Its blobs were verified when ITS push
          committed; never re-hashed.
        * re-keyed clone: received descriptor's records must hash (metadata
          content checksum) to the SAME checksum as the already-held twin —
          content identical, so every blob is already present and verified.
        * new-content layer (received, or an on-disk orphan of a crashed
          push): deep incremental check — records must match checksum and
          config lock, and every chunk must pass ``_blob_ok`` (verified on
          receipt, committed-known, or re-hashed now). Outside the
          crash-recovery case no byte is ever hashed twice.
        * all layers: the chain checksums are re-keyed and re-checked
          link by link (metadata-only), so the re-key walk the source did
          is independently recomputed at the remote.
        """
        stats = self.stats
        if self._committed_layers is None:       # offline path: no negotiate
            self._scan_committed(manifest.name)
        parent_chain: Optional[str] = None
        for lid in manifest.layer_ids:
            received = self._received_layers.get(lid)
            if received is None and lid in self._committed_layers and \
                    self.store.has_layer(lid):
                layer = self.store.read_layer(lid)
                want = config.layer_checksums.get(lid)
                if layer.checksum != want:
                    raise PushRejected(
                        f"layer {lid}: remote holds a different checksum "
                        "trace for this id (in-place mutation without a "
                        "new id?)")
                stats.layers_dedup += 1
            else:
                if received is None:
                    # an on-disk descriptor NOT reachable from a committed
                    # manifest is an orphan of a crashed push: re-verify it
                    # like a received layer, never trust it
                    if not self.store.has_layer(lid):
                        raise PushRejected(f"layer {lid}: neither received "
                                           "nor already held")
                    layer = self.store.read_layer(lid, use_cache=False)
                else:
                    layer = received
                if content_checksum(layer.records) != layer.checksum or \
                        config.layer_checksums.get(lid) != layer.checksum:
                    raise PushRejected(
                        f"layer {lid}: received records do not match the "
                        "declared checksum/lock")
                # a re-key twin is only trustworthy if IT was verified by a
                # committed push — an orphan descriptor must not vouch
                twin_id = self.rekey.get(lid)
                twin = (self.store.read_layer(twin_id)
                        if twin_id and twin_id in self._committed_layers
                        and self.store.has_layer(twin_id)
                        else None)
                if twin is not None and twin.checksum == layer.checksum:
                    # content-identical clone of an already-verified layer
                    stats.layers_rekey_verified += 1
                else:
                    for rec in layer.records:
                        for h in rec.chunks:
                            if not self._blob_ok(h):
                                raise PushRejected(
                                    f"layer {lid}: missing or corrupt "
                                    f"blob {h[:12]}")
                    stats.layers_deep_verified += 1
            expected = chain_checksum(parent_chain, layer.checksum,
                                      layer.instruction.text)
            if expected != layer.chain or \
                    config.layer_chains.get(lid) != layer.chain:
                raise PushRejected(f"layer {lid}: chain re-key mismatch")
            parent_chain = layer.chain

        cfg_bytes = dumps(config.to_json()).encode()
        man_bytes = dumps(manifest.to_json()).encode()
        stats.bytes_meta += len(cfg_bytes) + len(man_bytes)
        # the manifest rename: batch-durability fsyncs coalesce here
        self.store.write_image(manifest, config)
        stats.bytes_sent = stats.bytes_payload + stats.bytes_meta
        return stats


_TRANSFER_BATCH = 32    # blobs in flight per pipeline wave


@dataclass
class ReplicaResult:
    """One destination's outcome in a fan-out: its PushStats on success,
    the captured failure otherwise. Failures are ISOLATED — a replica that
    rejects, corrupts a transfer or dies never blocks the others; a later
    ``replicate_fanout`` retry converges it (orphan blobs/descriptors are
    re-verified by the normal negotiate/probe crash-recovery path)."""

    stats: Optional[PushStats] = None
    error: Optional[str] = None
    exception: Optional[BaseException] = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass
class FanoutStats:
    """What one fan-out replication actually cost the SOURCE, plus the
    per-replica outcomes. ``negotiation_rounds`` and ``source_blob_reads``
    are the paper-style structural claims CI gates: the source walks its
    layer metadata once and reads each changed blob from its store exactly
    once, no matter how many replicas are behind."""

    replicas: List[ReplicaResult] = field(default_factory=list)
    negotiation_rounds: int = 0
    source_blob_reads: int = 0
    blobs_broadcast: int = 0     # unique blobs ANY replica was missing
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.replicas)

    @property
    def n_ok(self) -> int:
        return sum(1 for r in self.replicas if r.ok)


def replicate_fanout(src: LayerStore, remotes: Sequence,
                     name: str, tag: str) -> FanoutStats:
    """Fan-out delta replication: push ``name:tag`` to N replicas with ONE
    negotiated have-set and ONE source read pass.

    * One negotiation round: every replica answers the same O(#layers)
      metadata request (``DeltaReceiver.negotiate`` + ``probe_blobs``);
      the answers are unioned into a single plan mapping each missing blob
      to the replicas that need it — replicas missing different subsets
      get per-replica send lists carved from that one plan.
    * One source read pass: each blob any replica is missing is read from
      the source store exactly once (``FanoutStats.source_blob_reads``)
      and broadcast through the pipelined read -> send -> verify -> write
      path, bounded in-flight batches keeping peak memory at O(batch);
      layer descriptors are serialized once for all replicas.
    * Per-replica isolation: negotiation, transfer and commit failures are
      captured per replica (``ReplicaResult``); healthy replicas commit
      regardless, commits run concurrently so one straggler doesn't hold
      the rest, and a clean retry converges the failed ones.
    """
    t0 = time.perf_counter()
    problems = src.verify_image(name, tag, deep=False)   # once, not per N
    if problems:
        raise PushRejected(f"source image fails verification: {problems}")
    manifest, config = src.read_image(name, tag)
    layers = {lid: src.read_layer(lid) for lid in manifest.layer_ids}
    layer_meta = {lid: (layer.family, layer.checksum)
                  for lid, layer in layers.items()}
    total_refs = sum(len(rec.chunks) for layer in layers.values()
                     for rec in layer.records)
    total_payload = sum(layer.nbytes for layer in layers.values())

    stores = [r if isinstance(r, LayerStore) else LayerStore(str(r))
              for r in remotes]
    receivers = [DeltaReceiver(s) for s in stores]
    fan = FanoutStats(replicas=[ReplicaResult() for _ in stores])
    lock = threading.Lock()

    def fail(i: int, exc: BaseException) -> None:
        with lock:
            if fan.replicas[i].error is None:
                fan.replicas[i].error = f"{type(exc).__name__}: {exc}"
                # kept with its traceback: push_delta re-raises it, and a
                # transfer-failure frame pins at most ONE blob's bytes
                fan.replicas[i].exception = exc

    def alive(i: int) -> bool:
        return fan.replicas[i].error is None

    with contextlib.ExitStack() as stack:
        for s in stores:
            stack.enter_context(_BatchScope(s))

        # ---- ONE negotiation round: same request to every replica (the
        # independent exchanges run concurrently — each one scans its own
        # replica's metadata), the answers unioned into one plan
        # (blob -> replicas missing it). negotiation_rounds is MEASURED
        # from the receivers' exchange counters, not asserted.
        missing_layers: List[List[str]] = [[] for _ in stores]
        plans: Dict[int, Set[str]] = {}
        want: Dict[str, List[int]] = {}
        pool = hash_pool()

        def plan(i: int) -> None:
            try:
                recv = receivers[i]
                have = recv.negotiate(name, layer_meta)
                recv.stats.bytes_meta += have.exchange_bytes
                # the in-place-mutation gate, BEFORE any byte moves
                for lid, remote_checksum in have.held_checksums.items():
                    if layers[lid].checksum != remote_checksum:
                        raise PushRejected(
                            f"layer {lid}: remote holds a different "
                            "checksum trace for this id (in-place mutation "
                            "without a new id?)")
                # blob set-difference: only new-content layers' chunks
                need = sorted({h for lid in have.missing_layers
                               if lid not in have.rekey
                               for rec in layers[lid].records
                               for h in rec.chunks})
                missing_layers[i] = list(have.missing_layers)
                plans[i] = recv.probe_blobs(need) if need else set()
            except Exception as e:
                fail(i, e)

        if len(stores) > 1 and pool is not None:
            for f in [pool.submit(plan, i) for i in range(len(stores))]:
                f.result()
        else:
            for i in range(len(stores)):
                plan(i)
        for i in sorted(plans):
            if not alive(i):
                continue
            for h in plans[i]:
                want.setdefault(h, []).append(i)
        fan.negotiation_rounds = max(
            (r.negotiations for r in receivers), default=0)

        # ---- ONE source read pass, broadcast on the pipelined transfer:
        # one pool task per blob reads it (exactly once) and verifies +
        # writes the first replica inline — reads of other blobs overlap
        # with SHA verification exactly as the single-destination pipeline
        # always did — while the remaining replicas' receives fan out as
        # their own pool tasks (SHA releases the GIL, so N replicas verify
        # in parallel). Bounded in-flight waves keep memory at O(batch),
        # not O(delta) — and never O(N x delta).
        hashes = sorted(h for h, targets in want.items()
                        if any(alive(i) for i in targets))
        fan.blobs_broadcast = len(hashes)

        def receive(i: int, h: str, data: bytes) -> None:
            if not alive(i):
                return
            try:
                receivers[i].receive_blob(h, data)
            except Exception as e:
                fail(i, e)

        recv_futures: List[Future] = []

        def ship(h: str) -> None:
            targets = [i for i in want[h] if alive(i)]
            if not targets:
                return              # every taker died mid-transfer
            data = src.read_blob(h)
            with lock:
                fan.source_blob_reads += 1
            if pool is not None:
                recv_futures.extend(pool.submit(receive, i, h, data)
                                    for i in targets[1:])
                receive(targets[0], h, data)
            else:
                for i in targets:
                    receive(i, h, data)

        for off in range(0, len(hashes), _TRANSFER_BATCH):
            wave = hashes[off:off + _TRANSFER_BATCH]
            if pool is None or len(wave) <= 1:
                for h in wave:
                    ship(h)
            else:
                for f in [pool.submit(ship, h) for h in wave]:
                    f.result()
            # all ships joined, so no more receives get scheduled: drain
            for f in recv_futures:
                f.result()
            recv_futures.clear()

        # ---- per-replica finalize: descriptors (encoded ONCE for all
        # replicas), incremental verification, the manifest commit —
        # concurrent across replicas so a straggler only delays itself.
        encoded: Dict[str, bytes] = {}
        for i in range(len(stores)):
            if not alive(i):
                continue
            for lid in missing_layers[i]:
                if lid not in encoded:
                    encoded[lid] = dumps(layers[lid].to_json()).encode()

        def finalize(i: int) -> None:
            recv = receivers[i]
            for lid in missing_layers[i]:
                recv.receive_layer(layers[lid], encoded=encoded[lid])
            stats = recv.commit(manifest, config)
            # dedup accounting from record metadata (no per-blob stats):
            # everything the image references that did NOT cross the wire.
            stats.blobs_dedup = total_refs - stats.blobs_sent
            stats.bytes_deduped = total_payload - stats.bytes_payload
            stats.wall_s = time.perf_counter() - t0
            fan.replicas[i].stats = stats

        def safe_finalize(i: int) -> None:
            try:
                finalize(i)
            except Exception as e:
                fail(i, e)

        live = [i for i in range(len(stores)) if alive(i)]
        if len(live) > 1 and pool is not None:
            for f in [pool.submit(safe_finalize, i) for i in live]:
                f.result()
        else:
            for i in live:
                safe_finalize(i)
    fan.wall_s = time.perf_counter() - t0
    return fan


def push_delta(src: LayerStore, dst: LayerStore, name: str, tag: str,
               ) -> PushStats:
    """O(changed-bytes) push (module docstring): the single-destination
    form of ``replicate_fanout`` — one have-set negotiation, only missing
    layers + blobs over the pipelined transfer, incremental remote
    verification at commit. Failures re-raise instead of being isolated."""
    fan = replicate_fanout(src, [dst], name, tag)
    rep = fan.replicas[0]
    if rep.exception is not None:
        raise rep.exception
    return rep.stats


def pull_delta(src: LayerStore, dst: LayerStore, name: str, tag: str,
               ) -> PushStats:
    """Pull = push with the roles swapped: ``dst`` negotiates its own
    have-set against ``src`` and receives only the delta."""
    return push_delta(src, dst, name, tag)


# --------------------------------------------------------------- offline
def export_delta(src: LayerStore, name: str, tag: str,
                 base_tag: Optional[str] = None) -> bytes:
    """Self-checking offline bundle of ``name:tag`` relative to
    ``name:base_tag`` (everything, when base_tag is None) — the
    ``docker save`` analogue of ``push_delta`` for air-gapped moves."""
    manifest, config = src.read_image(name, tag)
    new_layers = [src.read_layer(lid) for lid in manifest.layer_ids]
    base_layers: List[LayerDescriptor] = []
    if base_tag is not None:
        base_manifest, _ = src.read_image(name, base_tag)
        base_layers = [src.read_layer(lid)
                       for lid in base_manifest.layer_ids]
    missing, rekey, chunks = diff_manifests(base_layers, new_layers)
    return encode_delta(DeltaBundle(
        name=name, tag=tag, base_tag=base_tag or "",
        manifest=manifest, config=config, layers=missing, rekey=rekey,
        blobs={h: src.read_blob(h) for h in sorted(chunks)}))


def import_delta(dst: LayerStore, data: bytes) -> PushStats:
    """Apply an offline bundle through the same receive + incremental
    verification path a live push uses (decode already content-address-
    verified every payload; the receiver re-verifies on receipt anyway —
    defense in depth, still only the new bytes)."""
    bundle = decode_delta(data)
    receiver = DeltaReceiver(dst)
    with _BatchScope(dst):
        # index committed holdings up front so receive_layer's immutability
        # gate and commit's twin checks apply exactly as on the live path
        receiver._scan_committed(bundle.name)
        receiver.rekey = dict(bundle.rekey)
        for h in sorted(bundle.blobs):
            receiver.receive_blob(h, bundle.blobs[h])
        for layer in bundle.layers:
            receiver.receive_layer(layer)
        stats = receiver.commit(bundle.manifest, bundle.config)
    return stats
