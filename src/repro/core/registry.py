"""Remote-registry model — paper §III.C (redeployment).

A "remote" is simply another LayerStore that *verifies everything it
receives*. Pushing an image copies missing blobs + layer descriptors +
manifest/config, then runs full verification at the destination. This is
the integrity gate the paper's C3/C4 must satisfy: a naive in-place
mutation (same layer id, new content) is REJECTED because the remote
already holds the old layer under that id with a different checksum trace;
a clone-before-inject (new layer id, re-keyed manifest) is ACCEPTED as a
legitimately new layer.
"""
from __future__ import annotations

from dataclasses import dataclass

from .store import LayerStore


class PushRejected(RuntimeError):
    pass


@dataclass
class PushStats:
    blobs_sent: int = 0
    blobs_dedup: int = 0
    layers_sent: int = 0
    layers_dedup: int = 0
    bytes_sent: int = 0


def push(src: LayerStore, dst: LayerStore, name: str, tag: str) -> PushStats:
    stats = PushStats()
    problems = src.verify_image(name, tag, deep=False)
    if problems:
        raise PushRejected(f"source image fails verification: {problems}")
    manifest, config = src.read_image(name, tag)

    for lid in manifest.layer_ids:
        layer = src.read_layer(lid)
        if dst.has_layer(lid):
            existing = dst.read_layer(lid)
            if existing.checksum != layer.checksum:
                # The paper's exact failure mode: same id, diverged content.
                raise PushRejected(
                    f"layer {lid}: remote holds a different checksum trace "
                    "for this id (in-place mutation without a new id?)")
            stats.layers_dedup += 1
        else:
            stats.layers_sent += 1
        for rec in layer.records:
            for h in rec.chunks:
                if dst.has_blob(h):
                    stats.blobs_dedup += 1
                else:
                    data = src.read_blob(h)
                    dst.write_blob(h, data)
                    stats.blobs_sent += 1
                    stats.bytes_sent += len(data)
        dst.write_layer(layer)
    dst.write_image(manifest, config)

    problems = dst.verify_image(name, tag, deep=True)
    if problems:
        raise PushRejected(f"post-push verification failed: {problems}")
    return stats


def pull(src: LayerStore, dst: LayerStore, name: str, tag: str) -> PushStats:
    return push(src, dst, name, tag)
