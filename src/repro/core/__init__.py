"""The paper's primary contribution: a content-addressed, layered artifact
store for model state with O(delta) in-place injection updates (the "code
injection method"), checksum re-keying, clone-before-inject, dedup and a
verifying registry — Docker's layer system re-built for JAX training state.
"""
from .chunker import (DEFAULT_CHUNK_BYTES, TensorRecord, bytes_to_tensor,
                      chunk_tensor, hash_chunks, hash_pool, iter_chunks,
                      sha256_hex, tensor_chunk_bytes, tensor_to_bytes)
from .delta import (BundleEntry, BundleIndex, DeltaBundle, DeltaFormatError,
                    compose_delta_records, decode_delta, decode_index,
                    encode_delta, encode_index, plan_bundle_chain)
from .diff import (ChunkEdit, LayerDiff, diff_image, diff_manifests,
                   diff_layer_fingerprint, diff_layer_host,
                   diff_tensor_records, locate_changed_layers)
from .fingerprint import (chunk_geometry, fingerprint_chunk_bytes_ref,
                          fingerprint_chunks, fingerprint_chunks_ref,
                          fingerprint_tree, fingerprint_tree_packed,
                          fingerprint_tree_ref, tree_pack_index)
from .inject import (StructureChangeError, apply_edits, clone_layer,
                     inject_image, inject_image_multi,
                     inject_payload_update)
from .manifest import (ImageConfig, Instruction, LayerDescriptor, Manifest,
                       chain_checksum, content_checksum, history_delta_chain,
                       injection_history_entry, new_uuid)
from .registry import (DeltaReceiver, FanoutStats, HaveSet, PassiveRegistry,
                       PushRejected, PushStats, RelayNode, RepairFailed,
                       RepairReport, RepairSession, ReplicaResult,
                       export_delta, import_delta, pull, pull_delta, push,
                       push_delta, repair_image, replicate_fanout,
                       squash_deltas, verify_squashed_bundle)
from .store import BuildReport, HoldingsIndex, LayerStore

__all__ = [
    "DEFAULT_CHUNK_BYTES", "TensorRecord", "bytes_to_tensor", "chunk_tensor",
    "hash_chunks", "hash_pool", "iter_chunks", "sha256_hex",
    "tensor_chunk_bytes", "tensor_to_bytes", "BundleEntry", "BundleIndex",
    "DeltaBundle", "DeltaFormatError", "compose_delta_records",
    "decode_delta", "decode_index", "diff_manifests", "encode_delta",
    "encode_index", "plan_bundle_chain",
    "ChunkEdit", "LayerDiff", "diff_image",
    "diff_layer_fingerprint", "diff_layer_host", "diff_tensor_records",
    "locate_changed_layers",
    "chunk_geometry", "fingerprint_chunk_bytes_ref", "fingerprint_chunks",
    "fingerprint_chunks_ref", "fingerprint_tree", "fingerprint_tree_packed",
    "fingerprint_tree_ref", "tree_pack_index",
    "StructureChangeError", "apply_edits", "clone_layer", "inject_image",
    "inject_image_multi", "inject_payload_update", "ImageConfig",
    "Instruction", "LayerDescriptor", "Manifest", "chain_checksum",
    "content_checksum", "history_delta_chain", "injection_history_entry",
    "new_uuid",
    "DeltaReceiver", "FanoutStats", "HaveSet", "PassiveRegistry",
    "PushRejected", "PushStats", "RelayNode", "RepairFailed", "RepairReport",
    "RepairSession", "ReplicaResult", "export_delta", "import_delta", "pull",
    "pull_delta", "push", "push_delta", "repair_image", "replicate_fanout",
    "squash_deltas", "verify_squashed_bundle",
    "BuildReport", "HoldingsIndex", "LayerStore",
]
