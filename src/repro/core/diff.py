"""C1 — targeted change detection ("use diff to check changes").

Given a stored layer and a new payload, find exactly which chunks changed.
Two detectors:

* ``diff_layer_host`` — chunk-granular SHA-256 compare on the host. The
  direct analogue of the paper's text diff. O(changed-layer bytes) of
  hashing but zero serialization of unchanged chunks to disk.

* ``diff_layer_fingerprint`` — TPU adaptation: a 64-bit on-device
  fingerprint per chunk (see core/fingerprint.py and the Pallas kernel) is
  compared against the fingerprints recorded at last save; only chunks whose
  fingerprint changed are pulled to host and SHA'd. The device->host traffic
  is O(16 B x chunks + changed bytes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .chunker import (TensorRecord, hash_chunks, iter_chunks,
                      tensor_chunk_bytes, tensor_to_bytes)
from .fingerprint import fingerprint_chunk_bytes_ref
from .manifest import LayerDescriptor


@dataclass
class ChunkEdit:
    tensor: str
    index: int          # chunk index within the tensor
    new_hash: str
    data: bytes
    # Fingerprint of the NEW chunk bytes ((xor, sum) int32 pair) when the
    # edited record carries a fingerprint sidecar — lets apply_edits keep
    # ``TensorRecord.fp`` alive across injection so the next build_image
    # COPY prefilter never falls back to a full re-hash.
    fp: Optional[Tuple[int, int]] = None


@dataclass
class LayerDiff:
    layer_id: str
    edits: List[ChunkEdit] = field(default_factory=list)
    structure_changed: bool = False   # shape/dtype/tree change => "compiled"
    added: List[str] = field(default_factory=list)
    removed: List[str] = field(default_factory=list)
    chunks_prefiltered: int = 0       # chunks skipped by the fingerprint
                                      # prefilter (no serialize, no SHA)

    @property
    def is_empty(self) -> bool:
        return (not self.edits and not self.structure_changed
                and not self.added and not self.removed)

    @property
    def injectable(self) -> bool:
        """The paper's interpreted-language condition: the stored bytes ARE
        the artifact (value-only change). Structure changes are 'compiled' —
        the derived artifacts must be rebuilt."""
        return not self.structure_changed


def _host_compare_tensor(rec, name: str, arr, diff: LayerDiff) -> None:
    """Serialize + SHA every chunk of one tensor and record the edits
    (the non-prefiltered compare, shared by both diff paths)."""
    data = tensor_to_bytes(arr)
    pieces = list(iter_chunks(data, rec.chunk_bytes))
    for i, h in enumerate(hash_chunks(pieces)):
        if h != rec.chunks[i]:
            fp = fingerprint_chunk_bytes_ref(
                pieces[i], rec.dtype, rec.chunk_bytes) \
                if rec.fp is not None else None
            diff.edits.append(ChunkEdit(name, i, h, bytes(pieces[i]), fp=fp))


def diff_layer_host(layer: LayerDescriptor,
                    payload: Dict[str, np.ndarray]) -> LayerDiff:
    diff = LayerDiff(layer_id=layer.layer_id)
    by_name = {r.name: r for r in layer.records}
    diff.added = sorted(set(payload) - set(by_name))
    diff.removed = sorted(set(by_name) - set(payload))
    if diff.added or diff.removed:
        diff.structure_changed = True
    for name, rec in by_name.items():
        if name not in payload:
            continue
        arr = payload[name]
        if tuple(int(s) for s in np.shape(arr)) != rec.shape or \
                str(arr.dtype) != rec.dtype:
            diff.structure_changed = True
            continue
        _host_compare_tensor(rec, name, arr, diff)
    return diff


def diff_layer_fingerprint(layer: LayerDescriptor,
                           payload: Dict[str, np.ndarray],
                           old_fps: Dict[str, np.ndarray],
                           new_fps: Dict[str, np.ndarray]) -> LayerDiff:
    """Fingerprint-prefiltered diff. ``old_fps``/``new_fps`` map tensor name
    -> (n_chunks, 2) int32 fingerprints (from core.fingerprint). Only chunks
    whose fingerprint changed are serialized + SHA'd — and only the changed
    chunk RANGES of a tensor are serialized (``tensor_chunk_bytes``), never
    the whole array. Tensors with no recorded old fingerprint fall back to
    the host SHA compare. ``diff.chunks_prefiltered`` counts the chunks the
    prefilter proved unchanged (zero serialize/hash cost).
    """
    diff = LayerDiff(layer_id=layer.layer_id)
    by_name = {r.name: r for r in layer.records}
    diff.added = sorted(set(payload) - set(by_name))
    diff.removed = sorted(set(by_name) - set(payload))
    if diff.added or diff.removed:
        diff.structure_changed = True
    for name, rec in by_name.items():
        if name not in payload:
            continue
        arr = payload[name]
        if tuple(int(s) for s in np.shape(arr)) != rec.shape or \
                str(arr.dtype) != rec.dtype:
            diff.structure_changed = True
            continue
        if name not in old_fps or name not in new_fps:
            # no fingerprint history: full host compare for this tensor
            _host_compare_tensor(rec, name, arr, diff)
            continue
        fp_old, fp_new = np.asarray(old_fps[name]), np.asarray(new_fps[name])
        if fp_old.shape[0] != len(rec.chunks) or \
                fp_new.shape[0] != len(rec.chunks):
            # fingerprint/record geometry mismatch (e.g. the store was
            # reopened with a different chunk_bytes): the prefilter is
            # meaningless — compare every chunk rather than silently
            # dropping out-of-range indices
            _host_compare_tensor(rec, name, arr, diff)
            continue
        changed = np.nonzero(np.any(fp_old != fp_new, axis=-1))[0]
        diff.chunks_prefiltered += len(rec.chunks) - int(changed.size)
        if changed.size == 0:
            continue
        idxs = [int(i) for i in changed.tolist()]
        pieces = [tensor_chunk_bytes(arr, i, rec.chunk_bytes) for i in idxs]
        for i, piece, h in zip(idxs, pieces, hash_chunks(pieces)):
            if h != rec.chunks[i]:
                # new fingerprint comes free from the already-computed table
                fp = (int(fp_new[i, 0]), int(fp_new[i, 1]))
                diff.edits.append(ChunkEdit(name, i, h, piece, fp=fp))
    return diff


def locate_changed_layers(layers: Sequence[LayerDescriptor],
                          payloads: Dict[str, Dict[str, np.ndarray]],
                          ) -> List[Tuple[LayerDescriptor, LayerDiff]]:
    """Walk the image's layers 'Dockerfile line by line' (paper §III.A) and
    return (layer, diff) pairs for every changed content layer — a tuple
    view over ``diff_image`` (the {layer_id: diff} form injection takes)."""
    by_id = {layer.layer_id: layer for layer in layers}
    return [(by_id[lid], d)
            for lid, d in diff_image(layers, payloads).items()]


def diff_manifests(base_layers: Sequence[LayerDescriptor],
                   new_layers: Sequence[LayerDescriptor],
                   ) -> Tuple[List[LayerDescriptor], Dict[str, str],
                              set]:
    """Metadata-level image delta for replication (core.delta /
    core.registry): (missing layers, re-key table, new chunk ids) of
    ``new_layers`` relative to ``base_layers``.

    A new layer whose family has a content-checksum-equal revision in the
    base is a re-keyed clone (same records, new chain) — its chunks are by
    definition already present wherever the base is. Everything else is
    new content; its chunk set minus the base's chunk set is what a
    DeltaBundle must carry.
    """
    base_ids = {layer.layer_id for layer in base_layers}
    by_family: Dict[Tuple[str, str], str] = {}
    base_chunks: set = set()
    for layer in base_layers:
        by_family.setdefault((layer.family, layer.checksum), layer.layer_id)
        for rec in layer.records:
            base_chunks.update(rec.chunks)

    missing: List[LayerDescriptor] = []
    rekey: Dict[str, str] = {}
    chunks: set = set()
    for layer in new_layers:
        if layer.layer_id in base_ids:
            continue
        missing.append(layer)
        twin = by_family.get((layer.family, layer.checksum))
        if twin is not None:
            rekey[layer.layer_id] = twin
            continue
        for rec in layer.records:
            chunks.update(h for h in rec.chunks if h not in base_chunks)
    return missing, rekey, chunks


def diff_tensor_records(old_layers: Sequence[LayerDescriptor],
                        new_layers: Sequence[LayerDescriptor],
                        ) -> Optional[set]:
    """Tensor-level sparse-update plan between two stored revisions of one
    image: the set of tensor names whose stored records differ (any chunk
    hash moved). Pure metadata — no blob is read — which is what lets a
    serving replica refresh O(changed tensors) instead of O(model) after a
    delta pull. Returns ``None`` when the change is structural (tensor
    added/removed, shape or dtype change): value-only injection can't have
    produced it, so callers must fall back to a full reload. Assumes tensor
    names are unique across the image's content layers (true for every
    checkpoint image; images violating it also get the full-reload answer
    via the ambiguity check below)."""
    def index(layers):
        recs: Dict[str, TensorRecord] = {}
        for layer in layers:
            if layer.empty:
                continue
            for r in layer.records:
                if r.name in recs:          # ambiguous name: no sparse plan
                    return None
                recs[r.name] = r
        return recs

    old, new = index(old_layers), index(new_layers)
    if old is None or new is None or set(old) != set(new):
        return None
    changed = set()
    for name, rec in new.items():
        prev = old[name]
        if prev.shape != rec.shape or prev.dtype != rec.dtype or \
                prev.chunk_bytes != rec.chunk_bytes:
            return None
        if prev.chunks != rec.chunks:
            changed.add(name)
    return changed


def diff_image(layers: Sequence[LayerDescriptor],
               payloads: Dict[str, Dict[str, np.ndarray]],
               old_fps: Optional[Dict[str, np.ndarray]] = None,
               new_fps: Optional[Dict[str, np.ndarray]] = None,
               ) -> Dict[str, LayerDiff]:
    """C1 over a whole image: one non-empty LayerDiff per targeted content
    layer, keyed by layer_id — the input unit of ``inject_image_multi``.
    Passing both fingerprint tables switches every layer to the prefiltered
    detector; otherwise the host SHA compare runs."""
    diffs: Dict[str, LayerDiff] = {}
    for layer in layers:
        if layer.empty:
            continue
        key = layer.instruction.arg
        if key not in payloads:
            continue
        if old_fps is not None and new_fps is not None:
            d = diff_layer_fingerprint(layer, payloads[key],
                                       old_fps, new_fps)
        else:
            d = diff_layer_host(layer, payloads[key])
        if not d.is_empty:
            diffs[layer.layer_id] = d
    return diffs
