"""C1 — targeted change detection ("use diff to check changes").

Given a stored layer and a new payload, find exactly which chunks changed.
Two detectors:

* ``diff_layer_host`` — chunk-granular SHA-256 compare on the host. The
  direct analogue of the paper's text diff. O(changed-layer bytes) of
  hashing but zero serialization of unchanged chunks to disk.

* ``diff_layer_fingerprint`` — TPU adaptation: a 64-bit on-device
  fingerprint per chunk (see core/fingerprint.py and the Pallas kernel) is
  compared against the fingerprints recorded at last save; only chunks whose
  fingerprint changed are pulled to host and SHA'd. The device->host traffic
  is O(16 B x chunks + changed bytes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .chunker import (hash_chunks, iter_chunks, tensor_chunk_bytes,
                      tensor_to_bytes)
from .manifest import LayerDescriptor


@dataclass
class ChunkEdit:
    tensor: str
    index: int          # chunk index within the tensor
    new_hash: str
    data: bytes


@dataclass
class LayerDiff:
    layer_id: str
    edits: List[ChunkEdit] = field(default_factory=list)
    structure_changed: bool = False   # shape/dtype/tree change => "compiled"
    added: List[str] = field(default_factory=list)
    removed: List[str] = field(default_factory=list)
    chunks_prefiltered: int = 0       # chunks skipped by the fingerprint
                                      # prefilter (no serialize, no SHA)

    @property
    def is_empty(self) -> bool:
        return (not self.edits and not self.structure_changed
                and not self.added and not self.removed)

    @property
    def injectable(self) -> bool:
        """The paper's interpreted-language condition: the stored bytes ARE
        the artifact (value-only change). Structure changes are 'compiled' —
        the derived artifacts must be rebuilt."""
        return not self.structure_changed


def _host_compare_tensor(rec, name: str, arr, diff: LayerDiff) -> None:
    """Serialize + SHA every chunk of one tensor and record the edits
    (the non-prefiltered compare, shared by both diff paths)."""
    data = tensor_to_bytes(arr)
    pieces = list(iter_chunks(data, rec.chunk_bytes))
    for i, h in enumerate(hash_chunks(pieces)):
        if h != rec.chunks[i]:
            diff.edits.append(ChunkEdit(name, i, h, bytes(pieces[i])))


def diff_layer_host(layer: LayerDescriptor,
                    payload: Dict[str, np.ndarray]) -> LayerDiff:
    diff = LayerDiff(layer_id=layer.layer_id)
    by_name = {r.name: r for r in layer.records}
    diff.added = sorted(set(payload) - set(by_name))
    diff.removed = sorted(set(by_name) - set(payload))
    if diff.added or diff.removed:
        diff.structure_changed = True
    for name, rec in by_name.items():
        if name not in payload:
            continue
        arr = payload[name]
        if tuple(int(s) for s in np.shape(arr)) != rec.shape or \
                str(arr.dtype) != rec.dtype:
            diff.structure_changed = True
            continue
        _host_compare_tensor(rec, name, arr, diff)
    return diff


def diff_layer_fingerprint(layer: LayerDescriptor,
                           payload: Dict[str, np.ndarray],
                           old_fps: Dict[str, np.ndarray],
                           new_fps: Dict[str, np.ndarray]) -> LayerDiff:
    """Fingerprint-prefiltered diff. ``old_fps``/``new_fps`` map tensor name
    -> (n_chunks, 2) int32 fingerprints (from core.fingerprint). Only chunks
    whose fingerprint changed are serialized + SHA'd — and only the changed
    chunk RANGES of a tensor are serialized (``tensor_chunk_bytes``), never
    the whole array. Tensors with no recorded old fingerprint fall back to
    the host SHA compare. ``diff.chunks_prefiltered`` counts the chunks the
    prefilter proved unchanged (zero serialize/hash cost).
    """
    diff = LayerDiff(layer_id=layer.layer_id)
    by_name = {r.name: r for r in layer.records}
    diff.added = sorted(set(payload) - set(by_name))
    diff.removed = sorted(set(by_name) - set(payload))
    if diff.added or diff.removed:
        diff.structure_changed = True
    for name, rec in by_name.items():
        if name not in payload:
            continue
        arr = payload[name]
        if tuple(int(s) for s in np.shape(arr)) != rec.shape or \
                str(arr.dtype) != rec.dtype:
            diff.structure_changed = True
            continue
        if name not in old_fps or name not in new_fps:
            # no fingerprint history: full host compare for this tensor
            _host_compare_tensor(rec, name, arr, diff)
            continue
        fp_old, fp_new = np.asarray(old_fps[name]), np.asarray(new_fps[name])
        if fp_old.shape[0] != len(rec.chunks) or \
                fp_new.shape[0] != len(rec.chunks):
            # fingerprint/record geometry mismatch (e.g. the store was
            # reopened with a different chunk_bytes): the prefilter is
            # meaningless — compare every chunk rather than silently
            # dropping out-of-range indices
            _host_compare_tensor(rec, name, arr, diff)
            continue
        changed = np.nonzero(np.any(fp_old != fp_new, axis=-1))[0]
        diff.chunks_prefiltered += len(rec.chunks) - int(changed.size)
        if changed.size == 0:
            continue
        idxs = [int(i) for i in changed.tolist()]
        pieces = [tensor_chunk_bytes(arr, i, rec.chunk_bytes) for i in idxs]
        for i, piece, h in zip(idxs, pieces, hash_chunks(pieces)):
            if h != rec.chunks[i]:
                diff.edits.append(ChunkEdit(name, i, h, piece))
    return diff


def locate_changed_layers(layers: Sequence[LayerDescriptor],
                          payloads: Dict[str, Dict[str, np.ndarray]],
                          ) -> List[Tuple[LayerDescriptor, LayerDiff]]:
    """Walk the image's layers 'Dockerfile line by line' (paper §III.A) and
    return diffs for every content layer whose payload is provided."""
    out: List[Tuple[LayerDescriptor, LayerDiff]] = []
    for layer in layers:
        if layer.empty:
            continue
        key = layer.instruction.arg
        if key in payloads:
            d = diff_layer_host(layer, payloads[key])
            if not d.is_empty:
                out.append((layer, d))
    return out
