"""LayerStore — the on-disk content-addressed layer store.

Layout (mirrors /var/lib/docker/overlay2 + image metadata):

    <root>/blobs/sha256/<h[:2]>/<h>     chunk payloads (dedup'd by content)
    <root>/layers/<layer_uuid>.json     LayerDescriptor
    <root>/images/<name>/<tag>.json     Manifest
    <root>/images/<name>/<config>.json  ImageConfig
    <root>/repositories.json            name -> {tag: manifest path}

All metadata writes are atomic (tmp + os.replace) so a crash mid-save never
leaves a referenced-but-corrupt image — the commit point is the manifest
rename. Blobs are immutable once written (content-addressed), which is what
makes clone-before-inject (C4) O(#chunk-refs) instead of O(bytes).

``build_image`` is the **Docker-faithful baseline** including the DLC cache
rules of paper §II.A:
  1. identical chain -> skip entirely ("Using cache"),
  2. instruction added/removed/altered -> rebuild that layer,
  3. COPY/ADD: compare the new payload's *content* against the cached
     layer — answered by the per-chunk fingerprint sidecar when present
     (one vectorized pass, ``BuildReport.chunks_prefiltered``; any
     fingerprint mismatch proves a miss, all-equal is taken as a hit),
     else by the full re-chunk + re-SHA the real Docker pays,
  4. RUN/CMD/ENV: compare the *literal instruction text* only,
and the fall-through rule: the first rebuilt layer invalidates every layer
after it (chain checksums force re-execution of all downstream builds).

I/O accounting: every fsync (file or directory) is counted in
``LayerStore.fsyncs`` and surfaced per build via ``BuildReport.fsyncs``;
``durability="batch"`` (see LayerStore) defers per-chunk fsyncs to one
concurrent flush at the manifest commit point.
"""
from __future__ import annotations

import io
import json
import os
import re
import tarfile
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..ft.faults import CrashInjected, fault_point
from ..ft.scrub import (ScrubFinding, ScrubReport, clear_cursor,
                        load_cursor, save_cursor)
from .chunker import (DEFAULT_CHUNK_BYTES, TensorRecord, assemble_tensor,
                      chunk_tensor, sha256_hex)
from .fingerprint import fingerprint_chunks_ref
from .manifest import (ImageConfig, Instruction, LayerDescriptor, Manifest,
                       chain_checksum, content_checksum, dumps, new_uuid)

_HEX_ID = re.compile(r"[0-9a-f]{32}|[0-9a-f]{64}")  # uuid4.hex / sha256 hex

# Directory fsyncs at the batch-durability commit point are independent
# blocking syscalls — issue them concurrently.
_IO_POOL_WORKERS = min(4, os.cpu_count() or 1)
_IO_POOL: Optional[object] = None
_IO_POOL_LOCK = threading.Lock()


def _io_pool():
    global _IO_POOL
    with _IO_POOL_LOCK:
        if _IO_POOL is None:
            from concurrent.futures import ThreadPoolExecutor
            _IO_POOL = ThreadPoolExecutor(max_workers=_IO_POOL_WORKERS,
                                          thread_name_prefix="repro-fsync")
    return _IO_POOL


def _atomic_write(path: str, data, fsync: bool = True) -> None:
    tmp = f"{path}.tmp.{os.getpid()}.{time.monotonic_ns()}"
    with open(tmp, "wb") as f:
        f.write(data)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, path)


def _fsync_path(path: str) -> None:
    """fsync a file's data or a directory's entries (missing paths are
    ignored: a deferred-dirty blob may have been GC'd before commit)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except FileNotFoundError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclass
class HoldingsIndex:
    """The store's committed holdings across EVERY image — the cross-image
    blob universe the delta registry negotiates against (built by
    ``LayerStore.holdings_index``).

    * ``committed_layers`` — every layer id reachable from ANY committed
      tag of ANY image. This is the trust boundary: "held" at this store
      means a member of this set; a descriptor file outside it is an
      orphan of a crashed push and must never vouch for anything.
    * ``by_family`` — ``(family, content_checksum) -> layer_id`` over the
      per-image tag window: the re-key table's lookup side. The twin may
      live under a DIFFERENT image name than the one being pushed —
      content-checksum equality over the chunk-hash list is what proves
      the blobs present, not the image namespace.
    * ``known_chunks`` — chunk ids referenced by the window-scanned
      committed layers: membership means present AND verified by the push
      that committed them, whatever image that was.
    * ``images`` — the image names scanned (diagnostics / accounting).
    """

    committed_layers: set = field(default_factory=set)
    by_family: Dict[Tuple[str, str], str] = field(default_factory=dict)
    known_chunks: set = field(default_factory=set)
    images: List[str] = field(default_factory=list)


@dataclass
class _HoldingsAux:
    """Refcount bookkeeping that makes a cached ``HoldingsIndex``
    incrementally maintainable (one aux per cached tag window).

    The index's sets are membership views over these counts: a layer id is
    committed while ``layer_refs > 0`` (summed over every (image, tag)
    that references it), a chunk is known while ``chunk_refs > 0`` (summed
    over the *windowed* layers that reference it), and the re-key table
    maps a ``(family, checksum)`` key to the lexicographically smallest of
    its live windowed members — so adds and subtracts commute and a
    remove+gc can never leave the index vouching for a swept blob.
    ``win_added`` records, per windowed (image, tag), exactly the layer
    ids whose chunks were indexed (a missing descriptor is skipped at add
    time, so subtraction must not guess). Any inconsistency — an
    unreadable descriptor at subtract time, an underflowing count, a tag
    overwrite — invalidates the whole cache entry and the next
    ``holdings_index`` call falls back to the full rebuild (the cold-start
    / repair path).
    """

    layer_refs: Dict[str, int] = field(default_factory=dict)
    win_layer_refs: Dict[str, int] = field(default_factory=dict)
    chunk_refs: Dict[str, int] = field(default_factory=dict)
    family_members: Dict[Tuple[str, str], set] = field(default_factory=dict)
    win_tags: Dict[str, List[str]] = field(default_factory=dict)
    win_added: Dict[Tuple[str, str], List[str]] = field(default_factory=dict)


class _HoldingsStale(Exception):
    """Internal: the incremental holdings update hit a case it cannot
    apply soundly — drop the cache entry, rebuild lazily."""


@dataclass
class BuildReport:
    """What a build actually did — benchmarks read these counters."""

    layers_built: int = 0
    layers_cached: int = 0
    layers_injected: int = 0
    layers_rekeyed: int = 0
    bytes_serialized: int = 0
    bytes_hashed: int = 0
    chunks_written: int = 0
    derivations_run: int = 0
    bytes_d2h: int = 0           # device->host traffic (fingerprint tables)
    chunks_prefiltered: int = 0  # chunks skipped via fingerprint prefilter
    fsyncs: int = 0              # fsync syscalls issued (files + dirs)
    rekey_walks: int = 0         # downstream chain-re-key walks performed
    manifest_commits: int = 0    # write_image commit points hit
    wall_seconds: float = 0.0
    # Per-layer cost attribution, keyed by the SOURCE image's layer_id
    # (the id the caller's diffs/providers are keyed by). Each entry:
    # {"chunks_written", "bytes_written", "rekeyed", "rederived"}.
    per_layer: Dict[str, Dict[str, int]] = field(default_factory=dict)

    _COUNTERS = ("layers_built", "layers_cached", "layers_injected",
                 "layers_rekeyed", "bytes_serialized", "bytes_hashed",
                 "chunks_written", "derivations_run", "bytes_d2h",
                 "chunks_prefiltered", "fsyncs", "rekey_walks",
                 "manifest_commits")

    def layer_entry(self, layer_id: str) -> Dict[str, int]:
        return self.per_layer.setdefault(
            layer_id, {"chunks_written": 0, "bytes_written": 0,
                       "rekeyed": 0, "rederived": 0})

    def merge(self, other: "BuildReport") -> None:
        for k in self._COUNTERS:
            setattr(self, k, getattr(self, k) + getattr(other, k))
        for lid, entry in other.per_layer.items():
            mine = self.layer_entry(lid)
            for k, v in entry.items():
                mine[k] = mine.get(k, 0) + v
        self.wall_seconds += other.wall_seconds


class LayerStore:
    """See module docstring. ``durability``:

    * ``"batch"`` (the default) — blob/layer writes skip the inline
      per-file fsync; at the commit point (``write_image``, before the
      manifest rename) the dirty FILES are fsync'd concurrently in one
      deferred batch, then their directories. Durability is equivalent to
      "full" once the manifest is visible — the fsyncs are deferred and
      overlapped, not skipped. The manifest rename remains the commit
      point, so a crash mid-save still leaves the previous image intact.
    * ``"full"``  — every blob/layer write is fsync'd before it is linked
      in (the seed behavior; one fsync per chunk). Only useful when a
      caller needs every write durable BEFORE a commit point exists —
      e.g. writing blobs it never intends to commit under a manifest.

    ``record_fingerprints`` — store a per-chunk fingerprint sidecar on each
    TensorRecord at build time (excluded from content checksums), enabling
    the COPY-cache prefilter in ``build_image``.
    """

    def __init__(self, root: str, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 durability: str = "batch", record_fingerprints: bool = True):
        if durability not in ("full", "batch"):
            raise ValueError(f"unknown durability mode {durability!r}")
        self.root = root
        self.chunk_bytes = chunk_bytes
        self.durability = durability
        self.record_fingerprints = record_fingerprints
        self.fsyncs = 0              # lifetime fsync count (files + dirs)
        self.commits = 0             # lifetime write_image commit count
        self._dirty_dirs: set = set()
        self._dirty_files: set = set()
        # paths this process knows are durable (fsync'd inline or at a
        # commit). A dedup hit on a path NOT in this set may be a torn
        # leftover of a crashed batch-mode save — batch mode re-fsyncs it
        # at the next commit instead of trusting bare existence.
        self._durable_paths: set = set()
        self._dirty_lock = threading.Lock()
        # gc() callbacks for state that references committed tags but lives
        # OUTSIDE the marked namespace (e.g. a PassiveRegistry's published
        # bundles) — each returns {stat: count} merged into the gc stats.
        self._gc_hooks: "list" = []
        # Layer descriptors are immutable once written (every revision gets
        # a fresh layer_id), so parsed descriptors are cached: the
        # incremental save path re-reads every layer of the parent image on
        # each save, and a 100+-record descriptor costs milliseconds to
        # re-parse. Bounded FIFO; blobs/manifests are NOT cached.
        self._layer_cache: "dict[str, LayerDescriptor]" = {}
        self._layer_cache_cap = 512
        # Tag listings are re-requested on every save (latest_step) but only
        # change at a manifest commit / image removal — cache per image
        # name, invalidated at exactly those two points.
        self._tags_cache: Dict[str, List[str]] = {}
        # Cross-image holdings index (see holdings_index): rebuilt lazily,
        # then maintained INCREMENTALLY at the two points that change
        # committed reachability — write_image applies the new manifest's
        # layer set, remove_image subtracts it (refcounted via
        # _HoldingsAux; any case the incremental path cannot apply soundly
        # drops the entry and the next call rebuilds). Keyed by the tag
        # window so receivers with different windows never share an entry.
        self._holdings_cache: Dict[int, "HoldingsIndex"] = {}
        self._holdings_aux: Dict[int, _HoldingsAux] = {}
        self._holdings_lock = threading.Lock()
        # Blob/layer paths pinned by an in-progress RepairSession
        # (core/registry.py): a quarantined-then-refetched layer descriptor
        # leaves gc()'s mark phase blind to the blobs it references, so the
        # session registers every path the damaged image reaches here and
        # gc's sweep spares them — the same exemption the batch-durability
        # dirty set gets. Guarded by _dirty_lock (gc snapshots both
        # together).
        self._protected_paths: set = set()
        # Retention leases: (name, tag) -> {owner: expiry (monotonic)}.
        # A relay fanning a delta to lagging children takes a lease on the
        # tags whose blobs those children may still need; retention
        # (remove_image via ckpt.prune_steps) refuses to collect a leased
        # tag until every lease is released (child committed) or expired
        # (child died). gc() is lease-safe transitively: it only sweeps
        # what no tagged manifest reaches, and the leased tag's manifest
        # stays. In-memory by design — leases protect in-flight fan-outs
        # of THIS process; a crashed relay's leases die with it, exactly
        # the expiry semantics a restart wants.
        self._leases: Dict[Tuple[str, str], Dict[str, float]] = {}
        self._lease_lock = threading.Lock()
        for sub in ("blobs/sha256", "layers", "images"):
            os.makedirs(os.path.join(root, sub), exist_ok=True)

    # ------------------------------------------------------------ durability
    def _write_file(self, path: str, data) -> None:
        full = self.durability == "full"
        _atomic_write(path, data, fsync=full)
        if full:
            self.fsyncs += 1
            self._durable_paths.add(path)
        else:
            with self._dirty_lock:
                self._dirty_files.add(path)
                self._dirty_dirs.add(os.path.dirname(path))

    def sync_for_commit(self) -> None:
        """Flush deferred durability: fsync every dirty file's data, then
        every dirty directory, each batch issued concurrently (independent
        syscalls — wall time is the slowest sync, not the sum). Called
        automatically by ``write_image`` (the commit point)."""
        with self._dirty_lock:
            files, self._dirty_files = self._dirty_files, set()
            dirs, self._dirty_dirs = self._dirty_dirs, set()
        for batch in (sorted(files), sorted(dirs)):
            if not batch:
                continue
            if len(batch) > 1 and _IO_POOL_WORKERS > 1:
                list(_io_pool().map(_fsync_path, batch))
            else:
                for p in batch:
                    _fsync_path(p)
            self.fsyncs += len(batch)
        self._durable_paths.update(files)

    # ---------------------------------------------------------------- leases
    def acquire_lease(self, name: str, tag: str, owner: str,
                      ttl_s: float) -> None:
        """Hold ``name:tag`` against retention for ``ttl_s`` seconds on
        behalf of ``owner``. Ref-counted by owner; re-acquiring refreshes
        the expiry (a retried push extends its children's leases)."""
        with self._lease_lock:
            self._leases.setdefault((name, tag), {})[owner] = \
                time.monotonic() + ttl_s

    def release_lease(self, name: Optional[str], owner: str,
                      tag: Optional[str] = None) -> int:
        """Release ``owner``'s lease on ``tag`` (or on every tag of
        ``name`` when tag is None — the child-committed case; or on every
        tag of EVERY image when name is None too — a relay whose child
        committed releases the whole cross-image base set it pinned at
        negotiate). Returns the number of leases released."""
        n = 0
        with self._lease_lock:
            for (nm, tg), owners in list(self._leases.items()):
                if (name is not None and nm != name) or \
                        (tag is not None and tg != tag):
                    continue
                if owners.pop(owner, None) is not None:
                    n += 1
                if not owners:
                    del self._leases[(nm, tg)]
        return n

    def lease_holders(self, name: str, tag: str) -> List[str]:
        """Owners with an unexpired lease on ``name:tag`` (expired entries
        are purged here — expiry needs no background thread)."""
        now = time.monotonic()
        with self._lease_lock:
            owners = self._leases.get((name, tag))
            if not owners:
                return []
            live = {o: exp for o, exp in owners.items() if exp > now}
            if live:
                self._leases[(name, tag)] = live
            else:
                del self._leases[(name, tag)]
            return sorted(live)

    def leased(self, name: str, tag: str) -> bool:
        return bool(self.lease_holders(name, tag))

    # ---------------------------------------------------------------- blobs
    def _blob_path(self, h: str) -> str:
        d = os.path.join(self.root, "blobs", "sha256", h[:2])
        return os.path.join(d, h)

    def has_blob(self, h: str) -> bool:
        return os.path.exists(self._blob_path(h))

    def write_blob(self, h: str, data) -> bool:
        """Returns True if a new blob was written (False = dedup hit)."""
        data = fault_point("store.write_blob", f"{self.root}:{h}", data)
        path = self._blob_path(h)
        if os.path.exists(path):
            if self.durability == "batch" and path not in self._durable_paths:
                # existence alone doesn't prove durability: this could be
                # the un-fsynced leftover of a crashed batch-mode save —
                # re-fsync it at the next commit before referencing it
                with self._dirty_lock:
                    self._dirty_files.add(path)
                    self._dirty_dirs.add(os.path.dirname(path))
            return False
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._write_file(path, data)
        return True

    def read_blob(self, h: str) -> bytes:
        with open(self._blob_path(h), "rb") as f:
            data = f.read()
        return fault_point("store.read_blob", f"{self.root}:{h}", data)

    def ensure_blob_durable(self, h: str) -> None:
        """Schedule durability for a blob ADOPTED from disk (an orphan of
        a crashed push that re-hashed intact). Existence does not prove
        the bytes ever hit stable storage — the crashed writer may have
        died before its deferred fsync — so an adopter must re-arm the
        fsync: inline under durability="full", at the next commit point
        under "batch". Idempotent and free for already-durable paths."""
        path = self._blob_path(h)
        if path in self._durable_paths:
            return
        if self.durability == "full":
            _fsync_path(path)
            _fsync_path(os.path.dirname(path))
            self.fsyncs += 2
            self._durable_paths.add(path)
        else:
            with self._dirty_lock:
                self._dirty_files.add(path)
                self._dirty_dirs.add(os.path.dirname(path))

    def drop_blob(self, h: str) -> bool:
        """Delete one blob (caller must know it is unreferenced — e.g. a
        torn orphan of a crashed push, detected by content-address
        mismatch). Returns False if it didn't exist."""
        path = self._blob_path(h)
        try:
            os.remove(path)
        except OSError:
            return False
        self._durable_paths.discard(path)
        with self._dirty_lock:
            self._dirty_files.discard(path)
        return True

    # ----------------------------------------------------------- quarantine
    def _quarantine_path(self, h: str) -> str:
        return os.path.join(self.root, "quarantine", h)

    def quarantine_blob(self, h: str) -> bool:
        """Move a corrupt blob out of the content-addressed namespace into
        ``<root>/quarantine/<h>`` (atomic rename — the bad bytes are
        preserved for forensics, the address is freed for a verified
        replacement). Unlike ``drop_blob`` this is safe on a blob that IS
        still referenced by committed manifests: the image goes from
        silently-corrupt to visibly-incomplete, which every reader already
        handles (``missing blob`` from ``verify_image``, ``OSError`` from
        ``read_blob``) and ``repair_image`` heals. Returns False if the
        blob didn't exist."""
        src = self._blob_path(h)
        dst = self._quarantine_path(h)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        try:
            os.replace(src, dst)
        except OSError:
            return False
        self._durable_paths.discard(src)
        with self._dirty_lock:
            self._dirty_files.discard(src)
        return True

    def quarantined_blobs(self) -> List[str]:
        """Content addresses currently held in quarantine."""
        d = os.path.join(self.root, "quarantine")
        if not os.path.isdir(d):
            return []
        return sorted(fn for fn in os.listdir(d) if _HEX_ID.fullmatch(fn))

    def purge_quarantine(self, h: Optional[str] = None) -> int:
        """Discard one quarantined blob (or all of them) for good — the
        operator's explicit override once the bad bytes are no longer
        interesting. Returns the number removed."""
        victims = [h] if h is not None else self.quarantined_blobs()
        n = 0
        for v in victims:
            try:
                os.remove(self._quarantine_path(v))
                n += 1
            except OSError:
                continue
        return n

    # ------------------------------------------------------ repair pinning
    def protect_paths(self, paths) -> None:
        """Pin absolute paths against the ``gc()`` sweep for the duration
        of a repair (see ``_protected_paths``). Idempotent."""
        with self._dirty_lock:
            self._protected_paths.update(paths)

    def unprotect_paths(self, paths) -> None:
        with self._dirty_lock:
            self._protected_paths.difference_update(paths)

    # --------------------------------------------------------------- layers
    def _layer_path(self, layer_id: str) -> str:
        return os.path.join(self.root, "layers", f"{layer_id}.json")

    def _cache_layer(self, layer: LayerDescriptor) -> None:
        if len(self._layer_cache) >= self._layer_cache_cap:
            self._layer_cache.pop(next(iter(self._layer_cache)))
        self._layer_cache[layer.layer_id] = layer

    def write_layer(self, layer: LayerDescriptor,
                    encoded: Optional[bytes] = None) -> None:
        """``encoded`` lets callers that already serialized the descriptor
        (e.g. the registry receive path, which counts its wire bytes) skip
        a second JSON encode — it must be ``dumps(layer.to_json())``."""
        self._write_file(self._layer_path(layer.layer_id),
                         encoded if encoded is not None
                         else dumps(layer.to_json()).encode())
        self._cache_layer(layer)

    def read_layer(self, layer_id: str, use_cache: bool = True
                   ) -> LayerDescriptor:
        if use_cache:
            cached = self._layer_cache.get(layer_id)
            if cached is not None:
                return cached
        with open(self._layer_path(layer_id), "rb") as f:
            layer = LayerDescriptor.from_json(json.loads(f.read()))
        self._cache_layer(layer)
        return layer

    def has_layer(self, layer_id: str) -> bool:
        return os.path.exists(self._layer_path(layer_id))

    # --------------------------------------------------------------- images
    def _image_dir(self, name: str) -> str:
        d = os.path.join(self.root, "images", name)
        os.makedirs(d, exist_ok=True)
        return d

    def write_image(self, manifest: Manifest, config: ImageConfig) -> None:
        d = self._image_dir(manifest.name)
        # a crash HERE is the classic torn-commit point: blobs/layers on
        # disk, manifest absent — the previous tag must stay authoritative
        fault_point("store.commit", self.root)
        # Commit point: flush any deferred (durability="batch") blob/layer
        # writes before the manifest becomes visible, then write config +
        # manifest fully synced regardless of durability mode.
        self.sync_for_commit()
        _atomic_write(os.path.join(d, f"{config.config_id}.json"),
                      dumps(config.to_json()).encode())
        # Manifest rename is the commit point.
        _atomic_write(os.path.join(d, f"{manifest.tag}.json"),
                      dumps(manifest.to_json()).encode())
        self.fsyncs += 2
        self.commits += 1
        self._tags_cache.pop(manifest.name, None)
        self._holdings_apply_commit(manifest)

    def read_image(self, name: str, tag: str) -> Tuple[Manifest, ImageConfig]:
        d = self._image_dir(name)
        with open(os.path.join(d, f"{tag}.json"), "rb") as f:
            manifest = Manifest.from_json(json.loads(f.read()))
        with open(os.path.join(d, f"{manifest.config_id}.json"), "rb") as f:
            config = ImageConfig.from_json(json.loads(f.read()))
        return manifest, config

    def has_image(self, name: str, tag: str) -> bool:
        return os.path.exists(os.path.join(self.root, "images", name, f"{tag}.json"))

    def list_tags(self, name: str, fresh: bool = False) -> List[str]:
        """``fresh=True`` bypasses the commit-point cache — required when
        ANOTHER process/store instance may have committed tags (the cache
        is only invalidated by this instance's own write_image /
        remove_image)."""
        cached = None if fresh else self._tags_cache.get(name)
        if cached is not None:
            return list(cached)
        d = os.path.join(self.root, "images", name)
        if not os.path.isdir(d):
            return []
        # Skip config blobs explicitly: their filenames are bare hex ids
        # (32-hex uuid4 / 64-hex sha256), never user tags.
        tags = sorted(stem for stem in (p[:-5] for p in os.listdir(d)
                                        if p.endswith(".json"))
                      if not _HEX_ID.fullmatch(stem))
        self._tags_cache[name] = tags
        return list(tags)

    def list_images(self) -> List[str]:
        """Every image name with a directory under ``images/`` — the
        namespace the cross-image holdings index and ``gc()`` walk."""
        d = os.path.join(self.root, "images")
        return sorted(n for n in os.listdir(d)
                      if os.path.isdir(os.path.join(d, n)))

    def holdings_index(self, tag_window: int = 8,
                       fresh: bool = False) -> HoldingsIndex:
        """Index this store's committed holdings across EVERY image (see
        ``HoldingsIndex``) — what ``DeltaReceiver.negotiate``/``commit``
        vouch from, so a blob committed under ``base`` answers the probe
        for a push of ``tenant3``.

        ``committed_layers`` covers every tag of every image — an id
        referenced only by an old tag of a sibling image must still be
        protected from in-place overwrite. Only the descriptor-READING
        work (the family/re-key index and ``known_chunks``) is bounded to
        the ``tag_window`` newest tags *per image*: missing a match there
        only costs extra deep verification or a resent blob, never
        correctness. Cached per window; invalidated by this instance's own
        ``write_image``/``remove_image`` (``fresh=True`` bypasses — needed
        only when ANOTHER process commits into the same root)."""
        if not fresh:
            with self._holdings_lock:
                cached = self._holdings_cache.get(tag_window)
            if cached is not None:
                return cached
        idx, aux = HoldingsIndex(), _HoldingsAux()
        for name in self.list_images():
            tags = self.list_tags(name)
            if tags:        # a fully-untagged image holds nothing
                idx.images.append(name)
            stags = sorted(tags, reverse=True)
            if stags:
                aux.win_tags[name] = list(stags)
            for i, tag in enumerate(stags):
                try:
                    m, _ = self.read_image(name, tag)
                except (OSError, ValueError, KeyError):
                    continue
                for lid in m.layer_ids:
                    aux.layer_refs[lid] = aux.layer_refs.get(lid, 0) + 1
                idx.committed_layers.update(m.layer_ids)
                if i >= tag_window:
                    continue
                self._win_add_manifest(idx, aux, name, tag, m)
        with self._holdings_lock:
            self._holdings_cache[tag_window] = idx
            self._holdings_aux[tag_window] = aux
        return idx

    # -------------------------------------- incremental holdings maintenance
    def _win_add_manifest(self, idx: HoldingsIndex, aux: _HoldingsAux,
                          name: str, tag: str, m: Manifest) -> None:
        """Index a manifest's layers into the windowed (family / chunk)
        side of the holdings, recording exactly what was added so a later
        window eviction can subtract it. Shared by the full rebuild and
        the incremental write_image path — equivalence by construction."""
        added: List[str] = []
        for lid in m.layer_ids:
            if not self.has_layer(lid):
                continue
            layer = self.read_layer(lid)
            added.append(lid)
            n = aux.win_layer_refs.get(lid, 0)
            aux.win_layer_refs[lid] = n + 1
            if n:
                continue
            key = (layer.family, layer.checksum)
            members = aux.family_members.setdefault(key, set())
            members.add(lid)
            idx.by_family[key] = min(members)
            for rec in layer.records:
                for h in rec.chunks:
                    c = aux.chunk_refs.get(h, 0)
                    aux.chunk_refs[h] = c + 1
                    if not c:
                        idx.known_chunks.add(h)
        aux.win_added[(name, tag)] = added

    def _win_sub_tag(self, idx: HoldingsIndex, aux: _HoldingsAux,
                     name: str, tag: str) -> None:
        """Subtract a tag evicted from the window: exactly the layers
        ``_win_add_manifest`` recorded for it, refcounted down."""
        for lid in aux.win_added.pop((name, tag), []):
            n = aux.win_layer_refs.get(lid, 0) - 1
            if n < 0:
                raise _HoldingsStale
            if n:
                aux.win_layer_refs[lid] = n
                continue
            del aux.win_layer_refs[lid]
            layer = self.read_layer(lid)    # unreadable -> stale -> rebuild
            key = (layer.family, layer.checksum)
            members = aux.family_members.get(key, set())
            members.discard(lid)
            if members:
                idx.by_family[key] = min(members)
            else:
                aux.family_members.pop(key, None)
                idx.by_family.pop(key, None)
            for rec in layer.records:
                for h in rec.chunks:
                    c = aux.chunk_refs.get(h, 0) - 1
                    if c < 0:
                        raise _HoldingsStale
                    if c:
                        aux.chunk_refs[h] = c
                    else:
                        del aux.chunk_refs[h]
                        idx.known_chunks.discard(h)

    def _holdings_apply_commit(self, manifest: Manifest) -> None:
        """write_image hook: fold the committed manifest into every cached
        window instead of invalidating wholesale (the ROADMAP incremental-
        maintenance item). Unsound cases degrade to invalidation."""
        name, tag = manifest.name, manifest.tag
        with self._holdings_lock:
            for window in list(self._holdings_cache):
                idx = self._holdings_cache[window]
                aux = self._holdings_aux.get(window)
                try:
                    if aux is None:
                        raise _HoldingsStale
                    tags = aux.win_tags.setdefault(name, [])
                    if tag in tags:     # tag overwrite: old layer set gone
                        raise _HoldingsStale
                    for lid in manifest.layer_ids:
                        aux.layer_refs[lid] = \
                            aux.layer_refs.get(lid, 0) + 1
                    idx.committed_layers.update(manifest.layer_ids)
                    if name not in idx.images:
                        idx.images.append(name)
                        idx.images.sort()
                    old_win = tags[:window]
                    tags.append(tag)
                    tags.sort(reverse=True)
                    new_win = tags[:window]
                    for t in old_win:               # at most one eviction
                        if t not in new_win:
                            self._win_sub_tag(idx, aux, name, t)
                    if tag in new_win:
                        self._win_add_manifest(idx, aux, name, tag,
                                               manifest)
                except (_HoldingsStale, OSError, ValueError, KeyError):
                    self._holdings_cache.pop(window, None)
                    self._holdings_aux.pop(window, None)

    def _holdings_apply_remove(self, name: str, tag: str,
                               manifest: Optional[Manifest]) -> None:
        """remove_image hook: subtract the removed tag's layer set from
        every cached window (manifest was read before the unlink; None
        means it was unreadable — invalidate)."""
        with self._holdings_lock:
            for window in list(self._holdings_cache):
                idx = self._holdings_cache[window]
                aux = self._holdings_aux.get(window)
                try:
                    if aux is None or manifest is None:
                        raise _HoldingsStale
                    tags = aux.win_tags.get(name, [])
                    if tag not in tags:
                        raise _HoldingsStale
                    old_win = tags[:window]
                    tags.remove(tag)
                    new_win = tags[:window]
                    for lid in manifest.layer_ids:
                        n = aux.layer_refs.get(lid, 0) - 1
                        if n < 0:
                            raise _HoldingsStale
                        if n:
                            aux.layer_refs[lid] = n
                        else:
                            aux.layer_refs.pop(lid, None)
                            idx.committed_layers.discard(lid)
                    if tag in old_win:
                        self._win_sub_tag(idx, aux, name, tag)
                    for t in new_win:               # at most one promotion
                        if t not in old_win:
                            m2, _ = self.read_image(name, t)
                            self._win_add_manifest(idx, aux, name, t, m2)
                    if not tags:
                        aux.win_tags.pop(name, None)
                        if name in idx.images:
                            idx.images.remove(name)
                except (_HoldingsStale, OSError, ValueError, KeyError):
                    self._holdings_cache.pop(window, None)
                    self._holdings_aux.pop(window, None)

    def remove_image(self, name: str, tag: str, force: bool = False) -> bool:
        """Unlink a tag's manifest (layers/blobs become GC fodder; run
        ``gc()`` to reclaim them). Returns False if the tag didn't exist —
        or if an unexpired retention lease holds it (a relay's lagging
        child still needs its blobs; ``force=True`` overrides, for callers
        that know the children are gone for good)."""
        if not force and self.leased(name, tag):
            return False
        try:                # read BEFORE unlink: the incremental holdings
            manifest, _ = self.read_image(name, tag)   # subtraction needs
        except (OSError, ValueError, KeyError):        # the layer set
            manifest = None
        try:
            os.remove(os.path.join(self.root, "images", name, f"{tag}.json"))
        except OSError:
            return False
        self._tags_cache.pop(name, None)
        self._holdings_apply_remove(name, tag, manifest)
        return True

    # ------------------------------------------------------------ build API
    def build_content_layer(self, instruction: Instruction,
                            payload: Dict[str, np.ndarray],
                            parent_chain: Optional[str],
                            report: BuildReport,
                            family: Optional[str] = None,
                            version: int = 1) -> LayerDescriptor:
        """Full (baseline) layer build: serialize + hash EVERY byte."""
        import dataclasses

        records: List[TensorRecord] = []
        for name in sorted(payload.keys()):
            # one host conversion per tensor (device leaves cross D2H once;
            # both the chunker and the fingerprint sidecar reuse it)
            arr = payload[name]
            arr = arr if isinstance(arr, np.ndarray) else np.asarray(arr)
            rec, pairs = chunk_tensor(name, arr, self.chunk_bytes)
            for h, piece in pairs:
                if self.write_blob(h, piece):
                    report.chunks_written += 1
                report.bytes_hashed += len(piece)
            report.bytes_serialized += rec.nbytes
            if self.record_fingerprints:
                fp = fingerprint_chunks_ref(arr, self.chunk_bytes)
                rec = dataclasses.replace(
                    rec, fp=tuple((int(a), int(b)) for a, b in fp.tolist()))
            records.append(rec)
        checksum = content_checksum(records)
        lid = new_uuid()     # fresh descriptor identity per revision
        layer = LayerDescriptor(
            layer_id=lid,
            version=version,
            instruction=instruction,
            checksum=checksum,
            chain=chain_checksum(parent_chain, checksum, instruction.text),
            records=records,
            empty=False,
            family=family or lid,
        )
        self.write_layer(layer)
        report.layers_built += 1
        return layer

    def build_config_layer(self, instruction: Instruction,
                           parent_chain: Optional[str],
                           report: BuildReport,
                           family: Optional[str] = None,
                           version: int = 1) -> LayerDescriptor:
        """Empty layer — paper §III.B: config layers are 'empty layers' whose
        rebuild does not change content checksums."""
        checksum = content_checksum([])
        lid = new_uuid()
        layer = LayerDescriptor(
            layer_id=lid,
            version=version,
            instruction=instruction,
            checksum=checksum,
            chain=chain_checksum(parent_chain, checksum, instruction.text),
            records=[],
            empty=True,
            family=family or lid,
        )
        self.write_layer(layer)
        report.layers_built += 1
        return layer

    def _copy_payload_matches(self, prev: LayerDescriptor,
                              payload: Dict[str, np.ndarray],
                              report: BuildReport) -> bool:
        """COPY/ADD cache check. Prefers the per-chunk fingerprint sidecar:
        any fingerprint mismatch proves the bytes changed (definite cache
        miss, no hashing at all); all-equal fingerprints are taken as a hit
        (a 64-bit prefilter — the same collision budget the incremental
        save path already accepts). Records without a sidecar use the seed
        behavior: full re-chunk + re-SHA of the payload.
        """
        by_name = {r.name: r for r in prev.records}
        if set(by_name) != set(payload):
            return False
        if prev.records and all(r.fp is not None for r in prev.records):
            candidate_chunks = 0
            for pname, rec in by_name.items():
                arr = payload[pname]
                if tuple(int(s) for s in np.shape(arr)) != rec.shape or \
                        str(arr.dtype) != rec.dtype:
                    return False
                new_fp = fingerprint_chunks_ref(np.asarray(arr),
                                                rec.chunk_bytes)
                if tuple((int(a), int(b)) for a, b in new_fp.tolist()) \
                        != rec.fp:
                    return False    # definite miss: full rebuild follows
                candidate_chunks += len(rec.chunks)
            # only a HIT skipped work — count prefiltered chunks here, not
            # on the miss path where everything gets re-serialized anyway
            report.chunks_prefiltered += candidate_chunks
            return True
        recs = []
        for pname in sorted(payload.keys()):
            rec, pairs = chunk_tensor(pname, payload[pname],
                                      self.chunk_bytes)
            report.bytes_hashed += sum(len(p) for _, p in pairs)
            recs.append(rec)
        return content_checksum(recs) == prev.checksum

    def build_image(self, name: str, tag: str,
                    instructions: Sequence[Instruction],
                    providers: Dict[str, Callable[[], Dict[str, np.ndarray]]],
                    parent: Optional[Tuple[str, str]] = None,
                    arch: str = "generic") -> Tuple[Manifest, ImageConfig, BuildReport]:
        """Docker-faithful build with DLC caching + fall-through.

        ``providers[arg]()`` materializes the payload for a content
        instruction (the analogue of reading build-context files for COPY or
        executing a RUN). For RUN instructions the provider is the
        *derivation* — it is re-executed on every rebuild, which is exactly
        the fall-through cost the paper attacks.
        """
        report = BuildReport()
        t0 = time.perf_counter()
        fsyncs0, commits0 = self.fsyncs, self.commits
        parent_layers: List[LayerDescriptor] = []
        if parent is not None and self.has_image(*parent):
            pm, _ = self.read_image(*parent)
            parent_layers = [self.read_layer(lid) for lid in pm.layer_ids]

        layer_ids: List[str] = []
        checksums: Dict[str, str] = {}
        chains: Dict[str, str] = {}
        history: List[dict] = []
        parent_chain: Optional[str] = None
        fell_through = False

        for i, ins in enumerate(instructions):
            prev = parent_layers[i] if i < len(parent_layers) else None
            use_cache = False
            if prev is not None and not fell_through:
                if prev.instruction.text != ins.text:
                    use_cache = False          # DLC rule 2: instruction altered
                elif ins.kind == "config":
                    use_cache = True           # DLC rule 4: literal text match
                elif ins.op in ("COPY", "ADD"):
                    # DLC rule 3: the NEW payload's content must be compared
                    # against the cached layer. When the cached records
                    # carry a fingerprint sidecar, a cache HIT costs one
                    # vectorized fingerprint pass (no chunk copy, no SHA);
                    # otherwise fall back to the Docker-faithful full
                    # serialize+hash of the build context.
                    payload = providers[ins.arg]()
                    use_cache = self._copy_payload_matches(prev, payload,
                                                           report)
                else:
                    # RUN: literal text only (rule 4) — Docker does NOT
                    # re-execute to compare outputs.
                    use_cache = True

            if use_cache and prev is not None:
                layer = prev
                # Chain must still be re-validated against the (possibly
                # rebuilt) parent; identical prefix keeps identical chains.
                expected_chain = chain_checksum(parent_chain, layer.checksum,
                                                ins.text)
                if expected_chain != layer.chain:
                    use_cache = False
                else:
                    report.layers_cached += 1

            if not (use_cache and prev is not None):
                fell_through = True            # everything below rebuilds
                if ins.kind == "config":
                    layer = self.build_config_layer(
                        ins, parent_chain, report,
                        family=prev.family if prev else None,
                        version=(prev.version + 1) if prev else 1)
                else:
                    payload = providers[ins.arg]()
                    if ins.op == "RUN":
                        report.derivations_run += 1
                    layer = self.build_content_layer(
                        ins, payload, parent_chain, report,
                        family=prev.family if prev else None,
                        version=(prev.version + 1) if prev else 1)

            layer_ids.append(layer.layer_id)
            checksums[layer.layer_id] = layer.checksum
            chains[layer.layer_id] = layer.chain
            history.append({"instruction": ins.text, "layer": layer.layer_id,
                            "cached": bool(use_cache and prev is not None)})
            parent_chain = layer.chain

        config = ImageConfig(config_id=new_uuid(), arch=arch, version=1,
                             layer_checksums=checksums, layer_chains=chains,
                             history=history)
        manifest = Manifest(name=name, tag=tag, layer_ids=layer_ids,
                            config_id=config.config_id)
        self.write_image(manifest, config)
        report.fsyncs = self.fsyncs - fsyncs0
        report.manifest_commits = self.commits - commits0
        report.wall_seconds = time.perf_counter() - t0
        return manifest, config, report

    # ------------------------------------------------------------- load API
    def load_layer_payload(self, layer: LayerDescriptor) -> Dict[str, np.ndarray]:
        return {r.name: assemble_tensor(r, self.read_blob) for r in layer.records}

    def load_image_payload(self, name: str, tag: str,
                           names: Optional[Sequence[str]] = None
                           ) -> Dict[str, np.ndarray]:
        """Assemble an image's tensors from their chunk blobs. ``names``
        restricts assembly to those tensors (the sparse-refresh path:
        O(changed tensors) of blob reads instead of O(image)); None loads
        everything."""
        manifest, _ = self.read_image(name, tag)
        want = None if names is None else set(names)
        out: Dict[str, np.ndarray] = {}
        for lid in manifest.layer_ids:
            layer = self.read_layer(lid)
            if layer.empty:
                continue
            for r in layer.records:
                if want is None or r.name in want:
                    out[r.name] = assemble_tensor(r, self.read_blob)
        return out

    # ---------------------------------------------------------- verification
    def verify_image(self, name: str, tag: str, deep: bool = True) -> List[str]:
        """Integrity check — the test C3 must bypass. Returns problems."""
        problems: List[str] = []
        manifest, config = self.read_image(name, tag)
        parent_chain: Optional[str] = None
        for lid in manifest.layer_ids:
            if not self.has_layer(lid):
                problems.append(f"missing layer {lid}")
                continue
            # integrity checks must look at the bytes on DISK, not the cache
            layer = self.read_layer(lid, use_cache=False)
            if content_checksum(layer.records) != layer.checksum:
                problems.append(f"layer {lid}: content checksum mismatch")
            if config.layer_checksums.get(lid) != layer.checksum:
                problems.append(f"layer {lid}: config lock mismatch")
            expected_chain = chain_checksum(parent_chain, layer.checksum,
                                            layer.instruction.text)
            if expected_chain != layer.chain or \
               config.layer_chains.get(lid) != layer.chain:
                problems.append(f"layer {lid}: chain mismatch")
            if deep and not layer.empty:
                for rec in layer.records:
                    for h in rec.chunks:
                        if not self.has_blob(h):
                            problems.append(f"layer {lid}: missing blob {h[:12]}")
                        elif sha256_hex(self.read_blob(h)) != h:
                            problems.append(f"layer {lid}: corrupt blob {h[:12]}")
            parent_chain = layer.chain
        return problems

    # ---------------------------------------------------------------- scrub
    def scrub(self, max_bytes: Optional[int] = None,
              max_items: Optional[int] = None,
              reset: bool = False) -> "ScrubReport":
        """Integrity walk over the WHOLE store — the detection half of the
        self-healing loop (``ft/scrub.py`` owns the result model,
        ``repair_image`` in core/registry.py consumes the findings).

        Two phases per pass:

        1. **metadata** (first slice of a pass only): every committed
           tag's manifest, config locks, layer content checksums and chain
           re-key links are re-verified from the bytes on disk (never the
           cache), and committed chunks are checked for existence —
           exactly ``verify_image(deep=False)``'s checks plus missing-blob
           detection, across the full namespace.
        2. **blobs**: every payload under ``blobs/sha256`` is re-hashed
           against its content address, shard by shard (256 shards). A
           mismatch on a committed blob is a ``corrupt_blob`` finding
           attributed to the first (image, tag, layer) that references
           it; unreferenced blobs are ``orphan_blob`` debris.

        ``max_bytes``/``max_items`` bound one slice's re-hash work (at
        shard granularity; at least one shard always makes progress) —
        when the budget runs out the position persists in
        ``<root>/scrub.cursor.json`` and the next call resumes there, so a
        fleet-scale store is scrubbed across many short slices. The
        attribution map is rebuilt each slice (cheap metadata reads); the
        byte-heavy re-hashing never repeats a shard within a pass.
        ``reset=True`` discards the cursor and starts a fresh pass.

        Paths belonging to the open batch transaction or pinned by an
        in-progress repair are skipped — they are not committed state.
        Losing the cursor (crash between slices) only costs re-scrubbed
        shards, never a false verdict.
        """
        t0 = time.perf_counter()
        rep = ScrubReport()
        if reset:
            clear_cursor(self.root)
        cursor = load_cursor(self.root)
        first_slice = cursor == 0
        with self._dirty_lock:
            in_flight = set(self._dirty_files) | set(self._protected_paths)

        # metadata walk: attribution map (every slice) + integrity
        # findings (first slice of the pass only — they would duplicate)
        refs: Dict[str, Tuple[str, str, str]] = {}
        committed_lids: set = set()
        flagged: set = set()            # (kind, id) dedup across shared refs
        for name in self.list_images():
            seen = False
            for tag in self.list_tags(name, fresh=True):
                try:
                    manifest, config = self.read_image(name, tag)
                except (OSError, ValueError, KeyError) as e:
                    if first_slice:
                        rep.findings.append(ScrubFinding(
                            "manifest_unreadable", detail=str(e),
                            image=name, tag=tag))
                    continue
                seen = True
                parent_chain: Optional[str] = None
                chain_broken = False
                for lid in manifest.layer_ids:
                    committed_lids.add(lid)
                    if not self.has_layer(lid):
                        if first_slice and ("missing_layer", lid) not in flagged:
                            flagged.add(("missing_layer", lid))
                            rep.findings.append(ScrubFinding(
                                "missing_layer", image=name, tag=tag,
                                layer_id=lid))
                        chain_broken = True
                        continue
                    try:
                        layer = self.read_layer(lid, use_cache=False)
                    except (OSError, ValueError, KeyError) as e:
                        if first_slice and ("layer_unreadable", lid) not in flagged:
                            flagged.add(("layer_unreadable", lid))
                            rep.findings.append(ScrubFinding(
                                "layer_unreadable", detail=str(e),
                                image=name, tag=tag, layer_id=lid))
                        chain_broken = True
                        continue
                    rep.layers_scanned += 1
                    if first_slice:
                        if content_checksum(layer.records) != layer.checksum \
                                and ("layer_checksum_mismatch", lid) not in flagged:
                            flagged.add(("layer_checksum_mismatch", lid))
                            rep.findings.append(ScrubFinding(
                                "layer_checksum_mismatch", image=name,
                                tag=tag, layer_id=lid))
                        if config.layer_checksums.get(lid) != layer.checksum \
                                and ("config_lock_mismatch", lid) not in flagged:
                            flagged.add(("config_lock_mismatch", lid))
                            rep.findings.append(ScrubFinding(
                                "config_lock_mismatch", image=name,
                                tag=tag, layer_id=lid))
                        if not chain_broken:
                            expected = chain_checksum(
                                parent_chain, layer.checksum,
                                layer.instruction.text)
                            if (expected != layer.chain or
                                    config.layer_chains.get(lid) != layer.chain) \
                                    and ("chain_mismatch", lid) not in flagged:
                                flagged.add(("chain_mismatch", lid))
                                rep.findings.append(ScrubFinding(
                                    "chain_mismatch", image=name, tag=tag,
                                    layer_id=lid))
                    for rec in layer.records:
                        for h in rec.chunks:
                            refs.setdefault(h, (name, tag, lid))
                            if first_slice and not self.has_blob(h) \
                                    and ("missing_blob", h) not in flagged:
                                flagged.add(("missing_blob", h))
                                rep.findings.append(ScrubFinding(
                                    "missing_blob", image=name, tag=tag,
                                    layer_id=lid, blob=h))
                    parent_chain = layer.chain
            if seen:
                rep.images_scanned += 1

        if first_slice:
            layers_dir = os.path.join(self.root, "layers")
            for fn in sorted(os.listdir(layers_dir)):
                lid = fn[:-5]
                if not fn.endswith(".json") or not _HEX_ID.fullmatch(lid) \
                        or lid in committed_lids:
                    continue
                if os.path.join(layers_dir, fn) in in_flight:
                    continue
                rep.findings.append(ScrubFinding(
                    "orphan_layer", detail="descriptor unreachable from "
                    "any committed tag", layer_id=lid))

        # blob phase: re-hash shards from the cursor until done or budget
        from ..ft.scrub import N_SHARDS
        blob_root = os.path.join(self.root, "blobs", "sha256")
        shard = cursor
        budget_hit = False
        while shard < N_SHARDS:
            d = os.path.join(blob_root, f"{shard:02x}")
            if os.path.isdir(d):
                for fn in sorted(os.listdir(d)):
                    if len(fn) != 64 or not _HEX_ID.fullmatch(fn):
                        continue
                    path = os.path.join(d, fn)
                    if path in in_flight:
                        continue
                    try:
                        with open(path, "rb") as f:
                            data = f.read()
                    except OSError:
                        continue
                    rep.blobs_scanned += 1
                    rep.bytes_scanned += len(data)
                    if sha256_hex(data) != fn:
                        where = refs.get(fn)
                        if where:
                            rep.findings.append(ScrubFinding(
                                "corrupt_blob",
                                detail="content re-hash mismatch",
                                image=where[0], tag=where[1],
                                layer_id=where[2], blob=fn))
                        else:
                            rep.findings.append(ScrubFinding(
                                "orphan_blob",
                                detail="unreferenced, fails re-hash",
                                blob=fn))
                    elif fn not in refs:
                        rep.findings.append(ScrubFinding(
                            "orphan_blob", detail="unreferenced", blob=fn))
            rep.shards_scanned += 1
            shard += 1
            if shard < N_SHARDS and (
                    (max_bytes is not None and rep.bytes_scanned >= max_bytes)
                    or (max_items is not None
                        and rep.blobs_scanned >= max_items)):
                budget_hit = True
                break

        if budget_hit:
            rep.next_shard = shard
            save_cursor(self.root, shard)
        else:
            rep.complete = True
            rep.next_shard = 0
            clear_cursor(self.root)
        rep.wall_s = time.perf_counter() - t0
        return rep

    # ------------------------------------------------------------------- GC
    def add_gc_hook(self, hook) -> None:
        """Register ``hook(store) -> {stat: count}`` to run at the end of
        every ``gc()`` — retention awareness for satellites that
        advertise committed tags (``PassiveRegistry.attach_gc`` prunes
        published bundles whose endpoint tags were swept). A hook that
        raises is skipped, never fails the sweep."""
        self._gc_hooks.append(hook)

    def gc(self) -> Dict[str, int]:
        """Mark-and-sweep of unreferenced blobs, layer descriptors and
        config blobs, across the WHOLE image namespace: the roots are
        every committed tag of every image (``list_images``), so a base
        blob shared by N tenant images survives ``remove_image`` of N-1 of
        them — only blobs no surviving manifest reaches are swept. Sweep
        spares paths belonging to an open batch-durability transaction
        (written but not yet flushed at a commit) — an un-fsynced blob of
        an in-flight save must never be deleted out from under its
        forthcoming manifest. Retention leases pin transitively: a leased
        tag's manifest cannot be removed (``remove_image`` refuses), its
        manifest stays a root, so everything it reaches — including blobs
        also reachable from OTHER images' removed tags — stays marked.
        Safe to run at any point between batch-mode transactions
        (CheckpointManager runs it after each commit); must not run
        concurrently with a ``durability="full"`` writer, whose pre-commit
        blobs are not tracked as dirty.
        """
        marked_blobs: set = set()
        marked_layers: set = set()
        marked_configs: set = set()
        images_dir = os.path.join(self.root, "images")
        for name in self.list_images():
            for tag in self.list_tags(name):
                try:
                    manifest, config = self.read_image(name, tag)
                except (OSError, ValueError, KeyError):
                    continue
                marked_configs.add(config.config_id)
                for lid in manifest.layer_ids:
                    marked_layers.add(lid)
                    if not self.has_layer(lid):
                        continue
                    try:
                        layer = self.read_layer(lid)
                    except (OSError, ValueError, KeyError):
                        # an unreadable (corrupt/quarantined) descriptor
                        # can't contribute marks — its blobs survive only
                        # via other references or the repair-protected set
                        continue
                    for rec in layer.records:
                        marked_blobs.update(rec.chunks)

        with self._dirty_lock:
            # exemptions: the open batch transaction's dirty files AND any
            # path pinned by an in-progress RepairSession (protect_paths)
            protected = set(self._dirty_files) | set(self._protected_paths)
        stats = {"layers_swept": 0, "blobs_swept": 0, "bytes_swept": 0,
                 "configs_swept": 0}

        layers_dir = os.path.join(self.root, "layers")
        for fn in os.listdir(layers_dir):
            lid = fn[:-5]
            if not fn.endswith(".json") or not _HEX_ID.fullmatch(lid) or \
                    lid in marked_layers:
                continue
            path = os.path.join(layers_dir, fn)
            if path in protected:
                continue
            try:
                os.remove(path)
            except OSError:
                continue
            self._layer_cache.pop(lid, None)
            self._durable_paths.discard(path)
            stats["layers_swept"] += 1

        blob_root = os.path.join(self.root, "blobs", "sha256")
        for sub in os.listdir(blob_root):
            d = os.path.join(blob_root, sub)
            if not os.path.isdir(d):
                continue
            for fn in os.listdir(d):
                if len(fn) != 64 or not _HEX_ID.fullmatch(fn) or \
                        fn in marked_blobs:
                    continue
                path = os.path.join(d, fn)
                if path in protected:
                    continue
                try:
                    size = os.path.getsize(path)
                    os.remove(path)
                except OSError:
                    continue
                self._durable_paths.discard(path)
                stats["blobs_swept"] += 1
                stats["bytes_swept"] += size

        for name in os.listdir(images_dir):
            d = os.path.join(images_dir, name)
            if not os.path.isdir(d):
                continue
            for fn in os.listdir(d):
                stem = fn[:-5] if fn.endswith(".json") else fn
                if not fn.endswith(".json") or not _HEX_ID.fullmatch(stem) \
                        or stem in marked_configs:
                    continue
                path = os.path.join(d, fn)
                if path in protected:
                    continue
                try:
                    os.remove(path)
                except OSError:
                    continue
                stats["configs_swept"] += 1
        for hook in list(self._gc_hooks):
            try:
                extra = hook(self) or {}
            except CrashInjected:
                raise           # a simulated SIGKILL inside a hook is the
                # sweeping process dying, not "a broken hook"
            except Exception:  # noqa: BLE001
                continue        # a broken hook must never break the sweep
            for k, v in extra.items():
                stats[k] = stats.get(k, 0) + int(v)
        return stats

    # ------------------------------------------- explicit decompose (export)
    def export_image(self, name: str, tag: str) -> bytes:
        """`docker save`-style bundled tar (manifest + config + layer tars).

        The *explicit* decomposition path of paper §III.A: everything is
        serialized through an intermediate archive.
        """
        manifest, config = self.read_image(name, tag)
        buf = io.BytesIO()
        with tarfile.open(fileobj=buf, mode="w") as tar:
            def add(name_: str, data: bytes) -> None:
                info = tarfile.TarInfo(name_)
                info.size = len(data)
                tar.addfile(info, io.BytesIO(data))

            add("manifest.json", dumps(manifest.to_json()).encode())
            add(f"{config.config_id}.json", dumps(config.to_json()).encode())
            for lid in manifest.layer_ids:
                layer = self.read_layer(lid)
                add(f"{lid}/json", dumps(layer.to_json()).encode())
                add(f"{lid}/VERSION", str(layer.version).encode())
                inner = io.BytesIO()
                with tarfile.open(fileobj=inner, mode="w") as ltar:
                    for rec in layer.records:
                        data = b"".join(self.read_blob(h) for h in rec.chunks)
                        info = tarfile.TarInfo(rec.name)
                        info.size = len(data)
                        ltar.addfile(info, io.BytesIO(data))
                add(f"{lid}/layer.tar", inner.getvalue())
        return buf.getvalue()

    def import_image(self, bundle: bytes) -> Tuple[str, str]:
        """`docker load` counterpart."""
        with tarfile.open(fileobj=io.BytesIO(bundle), mode="r") as tar:
            manifest = Manifest.from_json(
                json.loads(tar.extractfile("manifest.json").read()))
            config = ImageConfig.from_json(
                json.loads(tar.extractfile(f"{manifest.config_id}.json").read()))
            for lid in manifest.layer_ids:
                layer = LayerDescriptor.from_json(
                    json.loads(tar.extractfile(f"{lid}/json").read()))
                inner = tarfile.open(
                    fileobj=io.BytesIO(tar.extractfile(f"{lid}/layer.tar").read()))
                for rec in layer.records:
                    data = inner.extractfile(rec.name).read()
                    off = 0
                    for h in rec.chunks:
                        piece = data[off:off + rec.chunk_bytes]
                        off += len(piece)
                        self.write_blob(h, piece)
                self.write_layer(layer)
        self.write_image(manifest, config)
        return manifest.name, manifest.tag

    # -------------------------------------------- implicit decompose (inplace)
    def open_layer_inplace(self, layer_id: str) -> LayerDescriptor:
        """Paper §III.A *implicit* decomposition: read the layer descriptor
        straight out of the store ("/var/lib/docker/overlay2/<id>/") without
        any intermediate archive. Chunk blobs are then addressable directly.
        """
        return self.read_layer(layer_id)
