"""On-device chunk fingerprints — the TPU-native change detector (C1).

The paper diffs text on the host. At TPU scale the params live in HBM and
hauling bytes to the host to hash them costs O(bytes/PCIe-bw) per save. We
instead compute a 64-bit mixing fingerprint per chunk *on device* — reading
each byte once at HBM bandwidth — and ship only the (n_chunks, 2) int32
fingerprint table to the host. Chunks whose fingerprint changed since the
last save are then fetched and SHA-256'd for the store (the key+lock hash
stays SHA-256, faithful to the paper; the fingerprint is a pre-filter).

Both reductions (xor, wraparound-add) are associative + commutative, so the
result is bit-identical under any sharding/layout — required for a
distributed change detector.

The Pallas kernel in kernels/fingerprint/ implements the same mix with
explicit VMEM tiling; this module is the jnp path (and the kernel's oracle).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

# odd multipliers from splitmix64's constants (truncated to 32-bit, forced odd)
_C1 = np.uint32(0x9E3779B9)
_C2 = np.uint32(0x85EBCA6B)
_C3 = np.uint32(0xC2B2AE35)


def _to_u32_lanes(arr: jax.Array) -> jax.Array:
    """Bit-exact view of any array as a flat uint32 lane vector."""
    a = arr.reshape(-1)
    nbits = jnp.dtype(a.dtype).itemsize * 8
    if a.dtype == jnp.bool_:
        a = a.astype(jnp.uint8)
        nbits = 8
    if nbits == 64:
        a = jax.lax.bitcast_convert_type(a, jnp.uint32).reshape(-1)
        return a
    if nbits == 32:
        return jax.lax.bitcast_convert_type(a, jnp.uint32)
    # sub-32-bit: widen bit patterns (cheap, keeps all entropy)
    if nbits == 16:
        u = jax.lax.bitcast_convert_type(a, jnp.uint16)
    else:  # 8-bit
        u = jax.lax.bitcast_convert_type(a, jnp.uint8)
    return u.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("chunk_bytes",))
def fingerprint_chunks(arr: jax.Array, chunk_bytes: int = 1 << 20) -> jax.Array:
    """-> (n_chunks, 2) int32 fingerprints, chunk boundaries matching
    chunker.iter_chunks on the serialized bytes."""
    itemsize = jnp.dtype(arr.dtype).itemsize
    if arr.dtype == jnp.bool_:
        itemsize = 1
    lanes_per_elem = max(1, 4 // itemsize) if itemsize < 4 else 1
    elems_per_chunk = max(1, chunk_bytes // itemsize)
    n = arr.size
    n_chunks = max(1, -(-n // elems_per_chunk))

    u = _to_u32_lanes(arr)
    lanes_per_chunk = elems_per_chunk * (u.size // max(n, 1)) if n else 1
    # derive exactly: lanes per chunk = elems_per_chunk * lanes_per_elem for
    # sub/equal-32-bit dtypes; for 64-bit dtypes it's elems_per_chunk * 2.
    lanes_per_chunk = (elems_per_chunk * u.size) // max(n, 1) if n else 1
    pad = n_chunks * lanes_per_chunk - u.size
    u = jnp.pad(u, (0, pad))
    u = u.reshape(n_chunks, lanes_per_chunk)

    pos = jnp.arange(lanes_per_chunk, dtype=jnp.uint32)[None, :]
    mixed = (u * _C1) ^ (pos * _C2 + _C3)
    mixed = mixed ^ (mixed >> 15)
    mixed = mixed * _C3
    fp_xor = jax.lax.reduce(mixed, np.uint32(0),
                            jax.lax.bitwise_xor, dimensions=(1,))
    fp_sum = jnp.sum(mixed, axis=1, dtype=jnp.uint32)
    out = jnp.stack([fp_xor, fp_sum], axis=-1)
    return jax.lax.bitcast_convert_type(out, jnp.int32)


def fingerprint_tree(tree, chunk_bytes: int = 1 << 20) -> Dict[str, np.ndarray]:
    """Host-side convenience: name->fingerprints for a flat payload dict."""
    return {name: np.asarray(fingerprint_chunks(jnp.asarray(v), chunk_bytes))
            for name, v in tree.items()}


def fingerprint_chunks_ref(arr: np.ndarray, chunk_bytes: int = 1 << 20) -> np.ndarray:
    """Pure-numpy oracle (also the ref for the Pallas kernel)."""
    a = np.asarray(arr)
    if str(a.dtype) == "bfloat16":
        u = a.view(np.uint16).astype(np.uint32).reshape(-1)
        itemsize = 2
    elif a.dtype == np.bool_:
        u = a.astype(np.uint8).astype(np.uint32).reshape(-1)
        itemsize = 1
    elif a.dtype.itemsize == 8:
        u = a.reshape(-1).view(np.uint32)
        itemsize = 8
    elif a.dtype.itemsize == 4:
        u = a.reshape(-1).view(np.uint32)
        itemsize = 4
    elif a.dtype.itemsize == 2:
        u = a.reshape(-1).view(np.uint16).astype(np.uint32)
        itemsize = 2
    else:
        u = a.reshape(-1).view(np.uint8).astype(np.uint32)
        itemsize = 1
    n = a.size
    elems_per_chunk = max(1, chunk_bytes // itemsize)
    n_chunks = max(1, -(-n // elems_per_chunk))
    lanes_per_chunk = (elems_per_chunk * u.size) // max(n, 1) if n else 1
    pad = n_chunks * lanes_per_chunk - u.size
    u = np.pad(u, (0, pad)).reshape(n_chunks, lanes_per_chunk)
    pos = np.arange(lanes_per_chunk, dtype=np.uint32)[None, :]
    with np.errstate(over="ignore"):
        mixed = (u * _C1) ^ (pos * _C2 + _C3)
        mixed = mixed ^ (mixed >> np.uint32(15))
        mixed = mixed * _C3
        fp_xor = np.bitwise_xor.reduce(mixed, axis=1)
        fp_sum = np.add.reduce(mixed, axis=1, dtype=np.uint32)
    return np.stack([fp_xor, fp_sum], axis=-1).view(np.int32)
