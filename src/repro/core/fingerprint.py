"""On-device chunk fingerprints — the TPU-native change detector (C1).

The paper diffs text on the host. At TPU scale the params live in HBM and
hauling bytes to the host to hash them costs O(bytes/PCIe-bw) per save. We
instead compute a 64-bit mixing fingerprint per chunk *on device* — reading
each byte once at HBM bandwidth — and ship only the (n_chunks, 2) int32
fingerprint table to the host. Chunks whose fingerprint changed since the
last save are then fetched and SHA-256'd for the store (the key+lock hash
stays SHA-256, faithful to the paper; the fingerprint is a pre-filter).

Both reductions (xor, wraparound-add) are associative + commutative, so the
result is bit-identical under any sharding/layout — required for a
distributed change detector.

Two granularities:

* ``fingerprint_chunks`` — one tensor per call. Fine for a handful of big
  arrays, but a real checkpoint has hundreds of pytree leaves and one jitted
  dispatch + one D2H transfer *per leaf* is dispatch-bound.
* ``fingerprint_tree_packed`` — the whole checkpoint in ONE dispatch: every
  leaf's uint32 lanes are packed into a single padded ``(total_chunks,
  lanes)`` buffer with a host-side index table mapping buffer rows back to
  ``(tensor, chunk_idx)``. Rows narrower than the widest leaf are masked
  past their own width, so each row's fingerprint is bit-identical to the
  per-leaf path. A single ``(total_chunks, 2)`` table (8 B per chunk)
  crosses the host link.

The Pallas kernel in kernels/fingerprint/ implements the same mix with
explicit VMEM tiling; this module is the jnp path (and the kernel's oracle).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .chunker import dtype_itemsize

# odd multipliers from splitmix64's constants (truncated to 32-bit, forced odd)
_C1 = np.uint32(0x9E3779B9)
_C2 = np.uint32(0x85EBCA6B)
_C3 = np.uint32(0xC2B2AE35)


def _to_u32_lanes(arr: jax.Array) -> jax.Array:
    """Bit-exact view of any array as a flat uint32 lane vector."""
    a = arr.reshape(-1)
    nbits = jnp.dtype(a.dtype).itemsize * 8
    if a.dtype == jnp.bool_:
        a = a.astype(jnp.uint8)
        nbits = 8
    if nbits == 64:
        a = jax.lax.bitcast_convert_type(a, jnp.uint32).reshape(-1)
        return a
    if nbits == 32:
        return jax.lax.bitcast_convert_type(a, jnp.uint32)
    # sub-32-bit: widen bit patterns (cheap, keeps all entropy)
    if nbits == 16:
        u = jax.lax.bitcast_convert_type(a, jnp.uint16)
    else:  # 8-bit
        u = jax.lax.bitcast_convert_type(a, jnp.uint8)
    return u.astype(jnp.uint32)


def chunk_geometry(shape: Tuple[int, ...], dtype: str,
                   chunk_bytes: int) -> Tuple[int, int]:
    """-> (n_chunks, lanes_per_chunk) for a tensor, matching both
    chunker.iter_chunks boundaries on the serialized bytes and the lane
    layout produced by ``_to_u32_lanes`` (sub-32-bit dtypes widen to one
    lane per element; 64-bit dtypes split into two lanes per element)."""
    itemsize = dtype_itemsize(dtype)
    lanes_per_elem = 2 if itemsize == 8 else 1
    elems_per_chunk = max(1, chunk_bytes // itemsize)
    n = 1
    for s in shape:
        n *= int(s)
    if not shape:
        n = 1
    n_chunks = max(1, -(-n // elems_per_chunk))
    lanes_per_chunk = elems_per_chunk * lanes_per_elem if n else 1
    return n_chunks, lanes_per_chunk


def _mix(u: jax.Array, pos: jax.Array) -> jax.Array:
    """The multiply-xor-shift lane mix (identical in jnp/numpy/Pallas)."""
    mixed = (u * _C1) ^ (pos * _C2 + _C3)
    mixed = mixed ^ (mixed >> 15)
    return mixed * _C3


def _reduce_rows(mixed: jax.Array) -> jax.Array:
    fp_xor = jax.lax.reduce(mixed, np.uint32(0),
                            jax.lax.bitwise_xor, dimensions=(1,))
    fp_sum = jnp.sum(mixed, axis=1, dtype=jnp.uint32)
    out = jnp.stack([fp_xor, fp_sum], axis=-1)
    return jax.lax.bitcast_convert_type(out, jnp.int32)


@functools.partial(jax.jit, static_argnames=("chunk_bytes",))
def fingerprint_chunks(arr: jax.Array, chunk_bytes: int = 1 << 20) -> jax.Array:
    """-> (n_chunks, 2) int32 fingerprints, chunk boundaries matching
    chunker.iter_chunks on the serialized bytes."""
    n_chunks, lanes_per_chunk = chunk_geometry(
        tuple(arr.shape), str(arr.dtype), chunk_bytes)
    u = _to_u32_lanes(arr)
    pad = n_chunks * lanes_per_chunk - u.size
    u = jnp.pad(u, (0, pad))
    u = u.reshape(n_chunks, lanes_per_chunk)
    pos = jnp.arange(lanes_per_chunk, dtype=jnp.uint32)[None, :]
    return _reduce_rows(_mix(u, pos))


def _device_lanes_leaf(v):
    """jnp.asarray that survives disabled x64: 64-bit numpy leaves
    (arrays AND scalars — np.generic) are bit-viewed as uint32 lanes on
    the host (jnp.asarray would silently downcast them, making the
    fingerprint blind to low-order bits of the serialized value). The
    uint32 view is the exact lane stream ``_to_u32_lanes`` produces."""
    if isinstance(v, np.generic):
        v = np.asarray(v)
    if isinstance(v, np.ndarray) and v.dtype.itemsize == 8 and \
            v.dtype != np.bool_ and not getattr(jax.config, "jax_enable_x64",
                                                False):
        return jnp.asarray(np.ascontiguousarray(v).reshape(-1).view(np.uint32))
    return jnp.asarray(v)


def fingerprint_tree(tree, chunk_bytes: int = 1 << 20) -> Dict[str, np.ndarray]:
    """Host-side convenience: name->fingerprints for a flat payload dict.

    One device dispatch and one D2H transfer PER LEAF — kept as the
    dispatch-bound baseline that ``fingerprint_tree_packed`` is benchmarked
    against (benchmarks/run.py::bench_incremental_save).
    """
    out: Dict[str, np.ndarray] = {}
    for name, v in tree.items():
        n_chunks, lanes = chunk_geometry(tuple(np.shape(v)), str(v.dtype),
                                         chunk_bytes)
        fp = _fingerprint_packed((_device_lanes_leaf(v),),
                                 ((n_chunks, lanes),), lanes, "jnp", False)
        out[name] = np.asarray(fp)
    return out


# --------------------------------------------------------------------- packed
def tree_pack_index(tree, chunk_bytes: int
                    ) -> Tuple[List[Tuple[str, int, int]], int, int]:
    """Host-side index table for the packed buffer.

    -> ([(name, row_offset, n_chunks), ...], total_chunks, max_lanes).
    Row ``row_offset + j`` of the packed buffer holds chunk ``j`` of
    ``name`` — the map from packed rows back to (tensor, chunk_idx).
    """
    index: List[Tuple[str, int, int]] = []
    row = 0
    max_lanes = 1
    for name, v in tree.items():
        n_chunks, lanes = chunk_geometry(
            tuple(np.shape(v)), str(v.dtype), chunk_bytes)
        index.append((name, row, n_chunks))
        row += n_chunks
        max_lanes = max(max_lanes, lanes)
    return index, row, max_lanes


def _pack_rows(leaves: Tuple[jax.Array, ...],
               geom: Tuple[Tuple[int, int], ...],
               lanes: int) -> Tuple[jax.Array, jax.Array]:
    """Trace-time packing: (total_chunks, lanes) uint32 buffer + per-row
    width vector. Rows keep each leaf's OWN zero padding inside its width
    (bit-identical to the per-leaf path); columns past the width are
    masked out by the consumer."""
    rows = []
    for arr, (n_chunks, w) in zip(leaves, geom):
        u = _to_u32_lanes(arr)
        u = jnp.pad(u, (0, n_chunks * w - u.size)).reshape(n_chunks, w)
        if w < lanes:
            u = jnp.pad(u, ((0, 0), (0, lanes - w)))
        rows.append(u)
    u_all = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)
    widths = np.concatenate(
        [np.full(g[0], g[1], np.int32) for g in geom]) if geom else \
        np.zeros((0,), np.int32)
    return u_all, jnp.asarray(widths)


@functools.partial(jax.jit,
                   static_argnames=("geom", "lanes", "backend", "interpret"))
def _fingerprint_packed(leaves: Tuple[jax.Array, ...],
                        geom: Tuple[Tuple[int, int], ...],
                        lanes: int, backend: str, interpret: bool
                        ) -> jax.Array:
    u_all, widths = _pack_rows(leaves, geom, lanes)
    if backend == "pallas":
        from ..kernels.fingerprint.kernel import fingerprint_lanes
        return fingerprint_lanes(u_all, widths=widths, interpret=interpret)
    pos = jnp.arange(lanes, dtype=jnp.uint32)[None, :]
    mixed = _mix(u_all, pos)
    mixed = jnp.where(pos < widths.astype(jnp.uint32)[:, None],
                      mixed, jnp.uint32(0))
    return _reduce_rows(mixed)


def fingerprint_tree_packed(tree, chunk_bytes: int = 1 << 20, *,
                            backend: str = "jnp", interpret: bool = False,
                            stats: Optional[dict] = None
                            ) -> Dict[str, np.ndarray]:
    """Fingerprint an entire flat payload dict in ONE device dispatch.

    Drop-in replacement for ``fingerprint_tree``: returns the identical
    name -> (n_chunks, 2) int32 table (bit-for-bit), but issues a single
    fused jitted computation over a packed ``(total_chunks, max_lanes)``
    buffer and a single D2H transfer of the ``(total_chunks, 2)`` result,
    instead of one dispatch + one transfer per pytree leaf.

    ``backend``: "jnp" (XLA, also the CPU path) or "pallas" (the tiled TPU
    kernel in kernels/fingerprint/; ``interpret=True`` runs it on CPU).
    ``stats``: optional dict; accumulates "bytes_d2h" (fingerprint-table
    bytes shipped to host) and "device_dispatches".

    Memory note: leaves are padded to the widest leaf's lane count —
    mixed-itemsize trees pay up to 4x transient padding on the narrow
    leaves. Homogeneous checkpoints (the common case) pay only the final
    ragged chunk per leaf.
    """
    if not tree:
        return {}
    names = list(tree.keys())
    index, total_chunks, max_lanes = tree_pack_index(tree, chunk_bytes)
    leaves = tuple(_device_lanes_leaf(tree[name]) for name in names)
    geom = tuple(chunk_geometry(tuple(np.shape(tree[n])), str(tree[n].dtype),
                                chunk_bytes) for n in names)
    fp_all = np.asarray(_fingerprint_packed(leaves, geom, max_lanes,
                                            backend, interpret))
    if stats is not None:
        stats["bytes_d2h"] = stats.get("bytes_d2h", 0) + fp_all.nbytes
        stats["device_dispatches"] = stats.get("device_dispatches", 0) + 1
    return {name: fp_all[off:off + n] for name, off, n in index}


def fingerprint_chunks_ref(arr: np.ndarray, chunk_bytes: int = 1 << 20) -> np.ndarray:
    """Pure-numpy oracle (also the ref for the Pallas kernel)."""
    a = np.asarray(arr)
    if str(a.dtype) == "bfloat16":
        u = a.view(np.uint16).astype(np.uint32).reshape(-1)
        itemsize = 2
    elif a.dtype == np.bool_:
        u = a.astype(np.uint8).astype(np.uint32).reshape(-1)
        itemsize = 1
    elif a.dtype.itemsize == 8:
        u = a.reshape(-1).view(np.uint32)
        itemsize = 8
    elif a.dtype.itemsize == 4:
        u = a.reshape(-1).view(np.uint32)
        itemsize = 4
    elif a.dtype.itemsize == 2:
        u = a.reshape(-1).view(np.uint16).astype(np.uint32)
        itemsize = 2
    else:
        u = a.reshape(-1).view(np.uint8).astype(np.uint32)
        itemsize = 1
    n = a.size
    elems_per_chunk = max(1, chunk_bytes // itemsize)
    n_chunks = max(1, -(-n // elems_per_chunk))
    lanes_per_chunk = (elems_per_chunk * u.size) // max(n, 1) if n else 1
    pad = n_chunks * lanes_per_chunk - u.size
    u = np.pad(u, (0, pad)).reshape(n_chunks, lanes_per_chunk)
    pos = np.arange(lanes_per_chunk, dtype=np.uint32)[None, :]
    with np.errstate(over="ignore"):
        mixed = (u * _C1) ^ (pos * _C2 + _C3)
        mixed = mixed ^ (mixed >> np.uint32(15))
        mixed = mixed * _C3
        fp_xor = np.bitwise_xor.reduce(mixed, axis=1)
        fp_sum = np.add.reduce(mixed, axis=1, dtype=np.uint32)
    return np.stack([fp_xor, fp_sum], axis=-1).view(np.int32)


def fingerprint_tree_ref(tree, chunk_bytes: int = 1 << 20
                         ) -> Dict[str, np.ndarray]:
    """Numpy oracle for a whole flat payload dict (no device round-trip)."""
    return {name: fingerprint_chunks_ref(np.asarray(v), chunk_bytes)
            for name, v in tree.items()}


def fingerprint_chunk_bytes_ref(data, dtype: str,
                                chunk_bytes: int = 1 << 20
                                ) -> Optional[Tuple[int, int]]:
    """Fingerprint ONE serialized chunk — bit-identical to the row this
    chunk gets in ``fingerprint_chunks_ref`` over the whole tensor (lane
    positions restart at 0 per chunk; a partial final chunk zero-pads to
    the full lane width). Host-side, used to refresh the ``TensorRecord.fp``
    sidecar for injected chunks (only changed chunks ever pay this).

    Returns None for pathological chunk sizes that do not align to the
    dtype's itemsize (mirroring ``chunker.tensor_chunk_bytes``'s fallback):
    a mid-tensor chunk then splits elements across chunk boundaries and no
    per-chunk recompute can match the whole-tensor table — callers drop
    the sidecar instead of crashing.
    """
    from .chunker import bytes_to_tensor
    if chunk_bytes % dtype_itemsize(dtype) or \
            len(data) % dtype_itemsize(dtype):
        return None
    arr = bytes_to_tensor(bytes(data), (-1,), dtype)
    fp = fingerprint_chunks_ref(arr, chunk_bytes)
    return int(fp[0, 0]), int(fp[0, 1])
