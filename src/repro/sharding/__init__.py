from .ctx import activation_ctx, constrain
from .rules import (Recipe, batch_specs, cache_specs, opt_specs, param_specs_tree,
                    recipe_for)

__all__ = ["activation_ctx", "constrain", "Recipe", "batch_specs",
           "cache_specs", "opt_specs", "param_specs_tree", "recipe_for"]
