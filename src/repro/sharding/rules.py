"""Sharding recipes: map (arch, shape-kind) onto the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Batch always shards over DP = ("pod","data") (or what divides); "model" is
the intra-pod 16-wide axis used for TP / sequence-parallelism / cache
sharding depending on the recipe.

Recipes
-------
* ``tp``      — megatron-style tensor parallelism: attention heads, FFN
                hidden, expert FFN hidden and the vocab dim shard over
                "model". Requires n_heads % model_size == 0.
* ``sp``      — sequence parallelism: activations shard their SEQUENCE dim
                over "model"; weights stay replicated over "model" except
                the (padded) vocab dim and — when divisible — FFN / expert
                hidden dims. For archs whose head counts don't divide the
                mesh (gemma 8H, granite/musicgen 24H, minicpm3 40H).
* ``dp``      — pure data parallelism over the flattened mesh (small archs:
                mamba2, hymba); ZeRO-1 shards optimizer state.
* ``tp_ssm``  — TP over the SSD head-dim P axis (divisible for P=64).
Decode recipes shard the KV/latent cache's LENGTH dim over "model"
(sequence-sharded cache) and the vocab dim for logits; batch over DP.

Optimizer state (AdamW master/m/v) is additionally sharded ZeRO-1 style
over the DP axes on the largest divisible axis of each leaf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..models.config import ModelConfig


@dataclass(frozen=True)
class Recipe:
    name: str                       # tp | sp | dp | tp_ssm
    kind: str                       # train | prefill | decode


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str:
    return "model"


def _batch_axes_for(mesh: Mesh, batch: int,
                    include_model: bool = False) -> Tuple[str, ...]:
    """Largest prefix of DP axes (optionally + model) dividing the batch."""
    cand = dp_axes(mesh) + (("model",) if include_model else ())
    axes = []
    prod = 1
    for a in cand:
        size = mesh.shape[a]
        if batch % (prod * size) == 0:
            axes.append(a)
            prod *= size
    return tuple(axes)


def zero_axes_for(recipe: "Recipe", mesh: Mesh) -> Tuple[str, ...]:
    """ZeRO-1 axes: everything the params are replicated over."""
    if recipe.name == "dp":
        return dp_axes(mesh) + ("model",)
    if recipe.name == "sp":
        return dp_axes(mesh) + ("model",)   # weights replicated over model
    return dp_axes(mesh)


# ---------------------------------------------------------------- recipes
def recipe_for(cfg: ModelConfig, kind: str, mesh: Mesh) -> Recipe:
    """Baseline recipe selection (overridable via cfg.replace)."""
    m = mesh.shape["model"]
    if kind == "decode":
        return Recipe("decode", kind)
    if cfg.family == "ssm":
        return Recipe("tp_ssm" if (cfg.d_inner // cfg.ssm_heads) % m == 0
                      else "dp", kind)
    if cfg.family == "hybrid":
        return Recipe("dp", kind)
    if cfg.n_heads % m == 0:
        return Recipe("tp", kind)
    return Recipe("sp", kind)


# ----------------------------------------------------------- param specs
def _moe_hidden_divisible(cfg: ModelConfig, m: int) -> bool:
    return cfg.d_ff_expert % m == 0


def _moe_replicable(cfg: ModelConfig) -> bool:
    """Expert weights small enough to replicate per device (<= ~4 GB)."""
    return (cfg.n_layers * cfg.n_experts * 3 * cfg.d_model *
            cfg.d_ff_expert * 2) <= 8 << 30


def param_specs_tree(cfg: ModelConfig, recipe: Recipe, mesh: Mesh,
                     params_shape) -> Any:
    """PartitionSpec pytree matching the params tree.

    ``params_shape``: pytree of ShapeDtypeStruct (from models.param_specs).
    """
    m = mesh.shape["model"]
    tp = recipe.name in ("tp", "tp_sp")
    tp_ssm = recipe.name == "tp_ssm"
    sp = recipe.name == "sp"
    shard_ff = (tp or sp) and cfg.d_ff % m == 0 and cfg.d_ff > 0
    shard_fe = cfg.n_experts > 0 and _moe_hidden_divisible(cfg, m) and \
        (tp or (sp and not _moe_replicable(cfg)))
    shard_vocab = recipe.name != "dp"
    shard_heads = tp and cfg.n_heads % m == 0
    shard_kv_heads = tp and cfg.n_kv_heads % m == 0 and cfg.n_kv_heads > 0
    shard_p = (tp_ssm or (tp and cfg.has_ssm)) and cfg.ssm_heads > 0 and \
        (cfg.d_inner // cfg.ssm_heads) % m == 0

    def spec_for(path: str, ndim: int) -> P:
        def blocked(*s):
            """Prepend None for the stacked layer dim."""
            return P(*((None,) + s + (None,) * (ndim - 1 - len(s))))

        leaf = path.split("/")[-1]
        if path == "embed":
            return P("model", None) if shard_vocab else P()
        if path == "lm_head":
            return P(None, "model") if shard_vocab else P()
        if path == "final_norm":
            return P()
        # ---- blocks/* (leading dim = n_layers) ----
        if leaf in ("wq",):
            return blocked(None, "model") if shard_heads else blocked()
        if leaf in ("wk", "wv"):
            return blocked(None, "model") if shard_kv_heads else blocked()
        if leaf == "wo":
            return blocked("model") if shard_heads else blocked()
        if leaf in ("w_gate", "w_up") and cfg.n_experts > 0 and \
                "blocks" in path and ndim == 4:          # (L, E, d, fe)
            return blocked(None, None, "model") if shard_fe else blocked()
        if leaf == "w_down" and cfg.n_experts > 0 and ndim == 4:
            return blocked(None, "model") if shard_fe else blocked()
        if leaf in ("w_gate", "w_up"):                   # (L, d, ff)
            return blocked(None, "model") if shard_ff else blocked()
        if leaf == "w_down":                             # (L, ff, d)
            return blocked("model") if shard_ff else blocked()
        if leaf in ("w_z", "w_x"):                       # (L, d, H, P)
            return blocked(None, None, "model") if shard_p else blocked()
        if leaf == "conv_x_w":                           # (L, H, P, K)
            return blocked(None, "model") if shard_p else blocked()
        if leaf in ("conv_x_b", "gate_norm"):            # (L, H, P)
            return blocked(None, "model") if shard_p else blocked()
        if leaf == "out_proj":                           # (L, H, P, d)
            return blocked(None, "model") if shard_p else blocked()
        if leaf in ("wq_b", "wkv_b"):                    # (L, r, H, dh) MLA
            return blocked()
        return blocked()

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k2: walk(v, f"{path}/{k2}" if path else k2)
                    for k2, v in tree.items()}
        return spec_for(path, len(tree.shape))

    return walk(params_shape)


# ------------------------------------------------------------ batch specs
def batch_specs(cfg: ModelConfig, recipe: Recipe, mesh: Mesh,
                batch: int) -> Dict[str, P]:
    """Shardings for the input batch dict."""
    baxes = _batch_axes_for(mesh, batch, include_model=(recipe.name == "dp"))
    b = baxes if baxes else None
    specs = {"tokens": P(b, None), "labels": P(b, None),
             "mask": P(b, None)}
    if cfg.n_prefix_embeds:
        specs["prefix_embeds"] = P(b, None, None)
    return specs


# ------------------------------------------------------- activation rules
def activation_rules(cfg: ModelConfig, recipe: Recipe, mesh: Mesh,
                     batch: int) -> Dict[str, Optional[P]]:
    m = mesh.shape["model"]
    baxes = _batch_axes_for(mesh, batch,
                            include_model=(recipe.name == "dp"))
    b = baxes if baxes else None
    tp = recipe.name in ("tp", "tp_sp")
    sp = recipe.name == "sp"
    tp_ssm = recipe.name == "tp_ssm"
    shard_heads = tp and cfg.n_heads % m == 0
    shard_kv = tp and cfg.n_kv_heads % m == 0 and cfg.n_kv_heads > 0
    shard_ff = (tp or sp) and cfg.d_ff % m == 0 and cfg.d_ff > 0
    shard_p = (tp_ssm or (tp and cfg.has_ssm)) and cfg.ssm_heads > 0 and \
        (cfg.d_inner // cfg.ssm_heads) % m == 0

    rules: Dict[str, Optional[P]] = {}
    if recipe.kind == "decode":
        # batch over DP; cache length over model; logits vocab over model.
        rules["act_hidden"] = P(b, None)
        rules["cache_kv"] = P(b, "model", None, None)
        rules["cache_latent"] = P(b, "model", None)
        rules["logits"] = P(b, "model") if recipe.name != "dp" else P(b, None)
        return rules

    if sp:
        rules["act_hidden"] = P(b, "model", None)
        rules["act_q"] = P(b, "model", None, None)
        rules["act_kv"] = P(b, None, None, None)         # gathered for attn
        rules["act_kv_rep"] = P(b, None, None, None)
        rules["act_ffh"] = P(b, "model", None)
        rules["act_ssm"] = P(b, None, None, None)
        rules["logits_chunk"] = P(b, "model", None)
        if cfg.is_moe:
            if _moe_replicable(cfg):
                # small experts: replicate expert weights, run the MoE
                # fully shard-local (zero MoE collectives)
                rules["moe_local"] = P(b, "model", None)
            else:
                # gather tokens over "model" for local routing; expert
                # FFN hidden stays TP-sharded; output reduce-scatters.
                rules["act_moe_in"] = P(b, None, None)
                rules["act_moe_out"] = P(b, "model", None)
    elif tp or tp_ssm:
        # tp_sp: Megatron-SP — the residual stream (and so every norm /
        # elementwise fusion between blocks) is sequence-sharded over
        # "model"; XLA pairs the surrounding collectives as RS+AG.
        rules["act_hidden"] = P(b, "model", None) \
            if recipe.name == "tp_sp" else P(b, None, None)
        if recipe.name == "tp_sp":
            rules["act_block_in"] = P(b, None, None)   # the SP gather
        rules["act_q"] = P(b, None, "model", None) if shard_heads else None
        rules["act_kv"] = P(b, None, "model", None) if shard_kv else \
            (P(b, None, None, None) if tp else None)
        rules["act_kv_rep"] = P(b, None, "model", None) if shard_heads \
            else None
        rules["act_ffh"] = P(b, None, "model") if shard_ff else None
        rules["act_ssm"] = P(b, None, None, "model") if shard_p else None
        rules["logits_chunk"] = P(b, None, "model")
    else:  # dp
        rules["act_hidden"] = P(b, None, None)
        rules["logits_chunk"] = P(b, None, None)
    return rules


# ------------------------------------------------------------ cache specs
def cache_specs(cfg: ModelConfig, recipe: Recipe, mesh: Mesh,
                batch: int, cache_shape) -> Any:
    """PartitionSpec tree for the (layer-stacked) decode cache."""
    baxes = _batch_axes_for(mesh, batch)
    b = baxes if baxes else None

    def spec(path: str, ndim: int) -> P:
        leaf = path.split("/")[-1]
        if leaf in ("k", "v"):                   # (L, B, C, KVH, D)
            return P(None, b, "model", None, None)
        if leaf in ("c_kv", "k_rope"):           # (L, B, C, r)
            return P(None, b, "model", None)
        if leaf in ("conv_x", "conv_B", "conv_C"):   # (L, B, K-1, ...)
            return P(None, b)
        if leaf == "h":                          # (L, B, H, P, N)
            return P(None, b)
        return P(None, b)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k2: walk(v, f"{path}/{k2}" if path else k2)
                    for k2, v in tree.items()}
        return spec(path, len(tree.shape))

    return walk(cache_shape)


# -------------------------------------------------------------- optimizer
def opt_specs(param_spec_tree, params_shape, mesh: Mesh,
              zero_axes: Tuple[str, ...]) -> Any:
    """ZeRO-1: shard each optimizer leaf over zero_axes on its largest
    axis that (a) is unsharded in the param spec and (b) divides evenly."""
    def one(spec: P, shape) -> P:
        entries = list(spec) + [None] * (len(shape.shape) - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    used.add(a)
        axes = tuple(a for a in zero_axes if a not in used)
        if not axes:
            return spec
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if n <= 1:
            return spec
        # choose the largest unsharded, divisible axis
        best, best_size = None, 0
        for i, (s, dim) in enumerate(zip(entries, shape.shape)):
            if s is None and dim % n == 0 and dim > best_size:
                best, best_size = i, dim
        if best is None:
            return spec
        entries[best] = axes if len(axes) > 1 else axes[0]
        return P(*entries)

    return jax.tree.map(one, param_spec_tree, params_shape)
