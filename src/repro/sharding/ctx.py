"""Activation-sharding context.

Model code is written once, distribution-agnostic. Inside a step function
the launcher installs a rule table (name -> PartitionSpec); ``constrain``
then pins named activations with with_sharding_constraint. Outside any
context (unit tests, CPU examples) it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_RULES: contextvars.ContextVar[Optional[Dict[str, PartitionSpec]]] = \
    contextvars.ContextVar("activation_rules", default=None)


def shard_map_fn():
    """``jax.shard_map`` (new home) falling back to
    ``jax.experimental.shard_map.shard_map`` (0.4.x)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    return shard_map


def current_mesh():
    """The mesh in scope for shard_map: ``jax.sharding.get_abstract_mesh``
    on newer jax; on older releases (0.4.x) the physical mesh entered via
    the Mesh context manager (see launch.mesh.mesh_context)."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


@contextlib.contextmanager
def activation_ctx(rules: Dict[str, PartitionSpec]):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def constrain(x: jax.Array, name: str) -> jax.Array:
    rules = _RULES.get()
    if rules is None or name not in rules:
        return x
    spec = rules[name]
    if spec is None:
        return x
    # pad the spec with None up to the array rank
    spec = PartitionSpec(*(tuple(spec) + (None,) * (x.ndim - len(spec))))
    return jax.lax.with_sharding_constraint(x, spec)
