"""Deterministic synthetic token pipeline.

Tokens are a pure function of (seed, step, global position) via a splitmix
hash, so any host can materialize exactly its shard without coordination —
the property a 1000-node data loader needs (no shared state, restart-safe:
resuming at step k regenerates the identical batch k).

``make_global_batch`` builds a jax.Array from per-shard callbacks
(jax.make_array_from_callback), the same path a multi-host loader uses.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclass
class SyntheticTokens:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for a step (any slice of it is shard-local)."""
        idx = (np.uint64(self.seed) * np.uint64(1_000_003) +
               np.uint64(step) * np.uint64(self.batch * (self.seq + 1)) +
               np.arange(self.batch * (self.seq + 1), dtype=np.uint64))
        with np.errstate(over="ignore"):
            toks = (_splitmix(idx) % np.uint64(self.vocab)).astype(np.int32)
        toks = toks.reshape(self.batch, self.seq + 1)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
            "mask": np.ones((self.batch, self.seq), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(ds: SyntheticTokens, step: int,
               prefix_embeds: Optional[np.ndarray] = None):
    b = ds.batch_at(step)
    if prefix_embeds is not None:
        b["prefix_embeds"] = prefix_embeds
        b["mask"][:, :prefix_embeds.shape[1]] = 0.0
    return b


def make_global_batch(mesh: Mesh, specs: Dict[str, PartitionSpec],
                      host_batch: Dict[str, np.ndarray]):
    """Assemble sharded jax.Arrays from per-shard callbacks (multi-host
    pattern; single-process here but the code path is identical)."""
    out = {}
    for name, arr in host_batch.items():
        spec = specs.get(name, PartitionSpec())
        sharding = NamedSharding(mesh, spec)
        out[name] = jax.make_array_from_callback(
            arr.shape, sharding, lambda idx, a=arr: a[idx])
    return out
