from .pipeline import SyntheticTokens, make_batch, make_global_batch

__all__ = ["SyntheticTokens", "make_batch", "make_global_batch"]
