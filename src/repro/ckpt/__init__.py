from .manager import CheckpointManager, CheckpointPolicy
from .reshard import reshard_restore

__all__ = ["CheckpointManager", "CheckpointPolicy", "reshard_restore"]
