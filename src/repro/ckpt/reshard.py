"""Elastic reshard-restore: load a checkpoint onto a DIFFERENT mesh.

Checkpoints store logical (unsharded) tensors chunk-addressed, so restoring
onto any mesh is a placement decision, not a data transformation: each
device materializes its shard by assembling only the chunks that overlap
its slice (here: full assembly + device_put, single-process; the chunk
store is what makes the per-host read O(shard) at real scale).

This is the node-failure story: lose devices -> rebuild a smaller mesh ->
reshard-restore -> continue (examples/elastic_restart.py).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .manager import CheckpointManager


def reshard_restore(mgr: CheckpointManager, mesh: Mesh, param_spec_tree,
                    opt_spec_tree=None, step: Optional[int] = None):
    """Restore + place: returns (params, opt_state, step) with leaves
    device_put against the given mesh/specs."""
    out = mgr.restore(step)
    if out is None:
        return None
    params, opt_state, saved_step = out

    def place(tree, specs):
        if specs is None:
            return jax.tree.map(jax.device_put, tree)
        return jax.tree.map(
            lambda a, s: jax.device_put(
                a, NamedSharding(mesh, s if s is not None else P())),
            tree, specs)

    params = place(params, param_spec_tree)
    if opt_spec_tree is not None:
        opt_state = place(opt_state, opt_spec_tree)
    return params, opt_state, saved_step
