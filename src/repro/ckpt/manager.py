"""CheckpointManager — training state as layered, content-addressed images.

A training checkpoint is an *image* whose layers mirror a Dockerfile:

    FROM <arch>                      (config layer, empty)
    COPY params/embed                (content layer)
    COPY params/blocks               (content layer — the big one)
    COPY params/head                 (content layer)
    RUN  adamw_init                  (content layer: m/v/master, derives
                                      from the params layers)
    ENV  step=<n>                    (config layer)

Two save modes, benchmarked against each other (the paper's comparison):

* ``save_full``  — Docker-faithful baseline: `build_image` with DLC cache
  rules; any param change re-serializes + re-hashes whole layers and falls
  through to everything below.
* ``save_incremental`` — the paper's code-injection method: per-chunk diff
  (optionally pre-filtered by on-device fingerprints), clone-before-inject,
  chunk-level writes, checksum re-key. Cost O(changed bytes), not O(state).

Async: serialization of the *diff payload* happens on the caller thread
(cheap: only changed chunks), blob/manifest writes go to a background
executor; `wait()` joins. Atomicity: the image manifest rename is the
commit point (see core.store), so a crash mid-save leaves the previous
checkpoint intact — tests/test_ft.py kills a save mid-flight to prove it.
"""
from __future__ import annotations

import json
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core import (BuildReport, Instruction, LayerStore, diff_layer_host,
                    fingerprint_tree, inject_image)
from ..core.diff import LayerDiff, diff_layer_fingerprint


def flatten_tree(tree, prefix="") -> Dict[str, np.ndarray]:
    """pytree -> flat {path: ndarray} with '/'-joined keys."""
    out: Dict[str, np.ndarray] = {}

    def walk(t, path):
        if isinstance(t, dict):
            for k2 in sorted(t.keys()):
                walk(t[k2], f"{path}/{k2}" if path else k2)
        else:
            out[path] = np.asarray(t)

    walk(tree, prefix)
    return out


def unflatten_tree(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


@dataclass
class CheckpointPolicy:
    every_steps: int = 100
    keep: int = 3
    incremental: bool = True          # the paper's technique (vs baseline)
    use_fingerprints: bool = False    # on-device change detection
    async_write: bool = True
    chunk_bytes: int = 1 << 20


class CheckpointManager:
    IMAGE = "ckpt"

    def __init__(self, root: str, arch: str,
                 policy: Optional[CheckpointPolicy] = None):
        self.policy = policy or CheckpointPolicy()
        self.store = LayerStore(root, chunk_bytes=self.policy.chunk_bytes)
        self.arch = arch
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._last_fps: Dict[str, np.ndarray] = {}
        self.last_report: Optional[BuildReport] = None

    # ------------------------------------------------------------ layout
    def _instructions(self) -> List[Instruction]:
        return [
            Instruction("FROM", self.arch, "config"),
            Instruction("COPY", "params/embed", "content"),
            Instruction("COPY", "params/blocks", "content"),
            Instruction("COPY", "params/head", "content"),
            Instruction("RUN", "opt_state", "content",
                        derives_from=[]),   # values evolve, not re-derived
            Instruction("ENV", "meta", "config"),
        ]

    def _payloads(self, params, opt_state, step: int
                  ) -> Dict[str, Dict[str, np.ndarray]]:
        flat = flatten_tree(params, "params")
        embed = {k: v for k, v in flat.items()
                 if k.startswith("params/embed")}
        blocks = {k: v for k, v in flat.items()
                  if k.startswith("params/blocks")}
        head = {k: v for k, v in flat.items()
                if not k.startswith(("params/embed", "params/blocks"))}
        opt = flatten_tree(opt_state, "opt")
        opt["opt/__step__"] = np.asarray([step], np.int32)
        return {"params/embed": embed, "params/blocks": blocks,
                "params/head": head, "opt_state": opt}

    # -------------------------------------------------------------- save
    def tag_of(self, step: int) -> str:
        return f"step-{step:08d}"

    def latest_step(self) -> Optional[int]:
        tags = [t for t in self.store.list_tags(self.IMAGE)
                if t.startswith("step-")]
        return max((int(t.split("-")[1]) for t in tags), default=None)

    def wait(self) -> Optional[BuildReport]:
        if self._pending is not None:
            self.last_report = self._pending.result()
            self._pending = None
        return self.last_report

    def save(self, step: int, params, opt_state) -> BuildReport:
        """Dispatches to full or incremental save per policy."""
        self.wait()
        payloads = self._payloads(params, opt_state, step)
        if self.policy.incremental and self.latest_step() is not None:
            fn = self._save_incremental
        else:
            fn = self._save_full
        if self.policy.async_write:
            self._pending = self._pool.submit(fn, step, payloads)
            return BuildReport()        # async: report available at wait()
        report = fn(step, payloads)
        self.last_report = report
        return report

    def _save_full(self, step: int,
                   payloads: Dict[str, Dict[str, np.ndarray]]) -> BuildReport:
        prev = self.latest_step()
        parent = (self.IMAGE, self.tag_of(prev)) if prev is not None else None
        providers = {k: (lambda p=v: p) for k, v in payloads.items()}
        ins = self._instructions()
        ins[-1] = Instruction("ENV", f"meta step={step}", "config")
        _, _, report = self.store.build_image(
            self.IMAGE, self.tag_of(step), ins, providers, parent=parent,
            arch=self.arch)
        self._gc()
        return report

    def _save_incremental(self, step: int,
                          payloads: Dict[str, Dict[str, np.ndarray]]
                          ) -> BuildReport:
        """The paper's injection path (C1-C4)."""
        prev = self.latest_step()
        manifest, _ = self.store.read_image(self.IMAGE, self.tag_of(prev))
        diffs: Dict[str, LayerDiff] = {}
        new_fps: Dict[str, np.ndarray] = {}
        for lid in manifest.layer_ids:
            layer = self.store.read_layer(lid)
            if layer.empty:
                continue
            key = layer.instruction.arg
            if key not in payloads:
                continue
            if self.policy.use_fingerprints and self._last_fps:
                fps = fingerprint_tree(payloads[key],
                                       self.policy.chunk_bytes)
                d = diff_layer_fingerprint(layer, payloads[key],
                                           self._last_fps, fps)
                new_fps.update(fps)
            else:
                d = diff_layer_host(layer, payloads[key])
            if not d.is_empty:
                diffs[lid] = d
        try:
            _, _, report = inject_image(
                self.store, self.IMAGE, self.tag_of(prev),
                self.tag_of(step), diffs,
                providers={k: (lambda p=v: p) for k, v in payloads.items()})
        except Exception:
            # structure changed ("compiled" case) -> rebuild fall-back
            report = self._save_full(step, payloads)
        if self.policy.use_fingerprints:
            self._last_fps = new_fps or self._last_fps
        self._gc()
        return report

    def _gc(self) -> None:
        tags = sorted(t for t in self.store.list_tags(self.IMAGE)
                      if t.startswith("step-"))
        for t in tags[:-self.policy.keep]:
            # old manifests removed; blobs stay dedup'd (a real deployment
            # runs a mark-and-sweep; references make deletion safe)
            try:
                os.remove(os.path.join(self.store.root, "images",
                                       self.IMAGE, f"{t}.json"))
            except OSError:
                pass

    # ------------------------------------------------------------ restore
    def restore(self, step: Optional[int] = None
                ) -> Optional[Tuple[Any, Any, int]]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        flat = self.store.load_image_payload(self.IMAGE, self.tag_of(step))
        opt_flat = {k[len("opt/"):]: v for k, v in flat.items()
                    if k.startswith("opt/")}
        saved_step = int(opt_flat.pop("__step__")[0])
        params_flat = {k[len("params/"):]: v for k, v in flat.items()
                       if k.startswith("params/")}
        return (unflatten_tree(params_flat), unflatten_tree(opt_flat),
                saved_step)
